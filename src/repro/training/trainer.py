"""Train-loop substrate: step builder + fault-tolerant driver.

``build_train_step`` turns any ``loss_fn(params, batch) -> scalar`` into a
jitted (state, batch) -> (state, metrics) step with:
  * gradient accumulation over microbatches (lax.scan - the standard
    compute/comm overlap lever: the DP all-reduce of microbatch i+1's
    grads overlaps the fwd/bwd of microbatch i under XLA async
    collectives; microbatch count is the §Perf knob),
  * global-norm clipping,
  * LR schedules,
  * optional gradient compression hook (see distributed.compression).

``Trainer`` drives the loop with periodic atomic checkpoints, resume
(pipeline ``seek``), and a preemption hook (SIGTERM -> checkpoint+exit:
the k8s/borg-style graceful eviction path).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import clip_by_global_norm


@dataclass
class TrainState:
    step: jnp.ndarray
    params: dict
    opt_state: object


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.opt_state), None),
    lambda aux, ch: TrainState(*ch))


def init_state(params, optimizer) -> TrainState:
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=optimizer.init(params))


def build_train_step(loss_fn: Callable, optimizer, schedule,
                     *, n_microbatches: int = 1, clip_norm: float = 1.0,
                     compress: Callable | None = None,
                     donate: bool = True):
    """loss_fn(params, batch) -> scalar. batch leading dim must divide
    n_microbatches (microbatch m = rows [m::n_microbatches] reshaped)."""

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step_fn(state: TrainState, batch: dict):
        params = state.params
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def slice_mb(x, m):
                b = x.shape[0] // n_microbatches
                return jax.lax.dynamic_slice_in_dim(x, m * b, b, axis=0)

            def body(carry, m):
                acc, loss_acc = carry
                mb = jax.tree_util.tree_map(lambda x: slice_mb(x, m), batch)
                loss, g = grads_of(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zero, jnp.float32(0.0)),
                jnp.arange(n_microbatches))
            grads = jax.tree_util.tree_map(
                lambda g: g / n_microbatches, grads)
            loss = loss / n_microbatches

        if compress is not None:
            grads = compress(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = schedule(state.step)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               params, lr)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt_state=new_opt)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return jax.jit(step_fn, donate_argnums=(0,) if donate else ())


@dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: str | None = None
    ckpt_every: int = 200
    keep_ckpts: int = 3
    log_every: int = 50


class Trainer:
    """Checkpointed, resumable, preemption-safe loop driver."""

    def __init__(self, cfg: TrainerConfig, train_step, state, pipeline,
                 *, log_fn: Callable = print):
        self.cfg = cfg
        self.train_step = train_step
        self.state = state
        self.pipeline = pipeline
        self.log_fn = log_fn
        self.metrics_history: list[dict] = []
        self._preempted = False

    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)

    def maybe_resume(self):
        if not self.cfg.ckpt_dir:
            return
        step = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if step is not None:
            self.state, _ = ckpt_lib.restore(self.cfg.ckpt_dir, self.state,
                                             step=step)
            self.pipeline.seek(int(step))
            self.log_fn(f"[trainer] resumed from step {step}")

    def _checkpoint(self):
        if self.cfg.ckpt_dir:
            step = int(jax.device_get(self.state.step))
            ckpt_lib.save(self.cfg.ckpt_dir, step, self.state,
                          keep=self.cfg.keep_ckpts)

    def run(self) -> dict:
        t0 = time.time()
        start = int(jax.device_get(self.state.step))
        for step in range(start, self.cfg.total_steps):
            batch = self.pipeline.next()
            batch = jax.tree_util.tree_map(jnp.asarray, batch)
            self.state, metrics = self.train_step(self.state, batch)
            if (step + 1) % self.cfg.log_every == 0:
                m = {k: float(jax.device_get(v)) for k, v in metrics.items()}
                m["step"] = step + 1
                self.metrics_history.append(m)
                self.log_fn(f"[trainer] step {step+1} "
                            f"loss {m['loss']:.4f} lr {m['lr']:.2e}")
            if (step + 1) % self.cfg.ckpt_every == 0 or self._preempted:
                self._checkpoint()
                if self._preempted:
                    self.log_fn("[trainer] preempted: checkpointed, exiting")
                    break
        self._checkpoint()
        last = self.metrics_history[-1] if self.metrics_history else {}
        return {"wall_s": time.time() - t0, "final": last,
                "history": self.metrics_history}
