"""Optimizers + LR schedules (hand-rolled; no optax in this environment).

Each optimizer is an (init, update) pair over arbitrary pytrees:
    state = init(params)
    new_params, new_state = update(grads, state, params, lr)

Schedules are step -> lr callables usable inside jit (pure jnp).
WSD (warmup-stable-decay) is included because minicpm-2b trains with it
(assigned-arch note in its config).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def clip_by_global_norm(grads, max_norm: float):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)]
    gnorm = jnp.sqrt(sum(leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return tree_map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params) -> AdamState:
        zeros = tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros,
                         tree_map(jnp.copy, zeros))

    def update(self, grads, state: AdamState, params, lr):
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
        nu = tree_map(lambda v, g: b2 * v + (1 - b2) *
                      jnp.square(g.astype(jnp.float32)), state.nu, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            u = (m / c1) / (jnp.sqrt(v / c2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        return tree_map(upd, params, mu, nu), AdamState(step, mu, nu)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: dict


@dataclass(frozen=True)
class SGD:
    momentum: float = 0.9
    nesterov: bool = False

    def init(self, params) -> SGDState:
        return SGDState(
            jnp.zeros((), jnp.int32),
            tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(self, grads, state: SGDState, params, lr):
        m = tree_map(lambda b, g: self.momentum * b + g.astype(jnp.float32),
                     state.momentum, grads)
        if self.nesterov:
            eff = tree_map(lambda b, g: self.momentum * b + g.astype(jnp.float32),
                           m, grads)
        else:
            eff = m
        new = tree_map(lambda p, u: (p.astype(jnp.float32) - lr * u)
                       .astype(p.dtype), params, eff)
        return new, SGDState(state.step + 1, m)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.0) -> Callable:
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * step / max(1, warmup)
        t = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return fn


def wsd_schedule(peak: float, warmup: int, stable: int, decay: int,
                 floor_frac: float = 0.1) -> Callable:
    """Warmup-Stable-Decay (MiniCPM, [arXiv:2404.06395] §4): linear warmup,
    long constant plateau, fast exponential-ish decay to floor."""

    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak * step / max(1, warmup)
        t = jnp.clip((step - warmup - stable) / max(1, decay), 0.0, 1.0)
        dec = peak * (floor_frac ** t)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, peak, dec))

    return fn
