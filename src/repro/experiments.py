"""End-to-end offline experiment harness (paper §5.1-§5.2 protocol).

Pipeline (all steps faithful to the paper, scaled-down world documented in
DESIGN.md §8):

  1. synthetic Ali-CCP-style world + 50/25/22.5/2.5 user split;
  2. train the four cascade models (DSSM, YDNN, DIN, DIEN) on the click
     log of the cascade-train users;
  3. precompute per-stage full-corpus scores; ground-truth clicks sampled
     once per (user, item);
  4. simulate EVERY action chain per user -> revenue matrices (this is
     the paper's training-sample generation for the reward model);
  5. train the personalized reward model (+ per-stage models for CRAS);
  6. evaluate GreenFlow / CRAS-* / EQUAL-* at a sweep of budgets with
     revenue@e realized against ground truth.

Used by tests/test_system.py (small), benchmarks/ (paper tables) and
examples/.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.cascade.engine import (CascadeModels, precompute_stage_scores,
                                  simulate_revenue_matrix)
from repro.core.action_chain import (ActionChainSet, ModelInstance, StageSpec,
                                     generate_action_chains)
from repro.core.baselines import (StageActionSpace, cras_allocation,
                                  equal_allocation)
from repro.core.primal_dual import allocate, dual_bisect
from repro.core.reward_model import (RewardModelConfig, chain_label_norm,
                                     denormalize_rewards, field_rce,
                                     reward_matrix, reward_matrix_chunked,
                                     reward_model_init)
from repro.data.synthetic import (World, WorldConfig, build_world, ctr_batch,
                                  split_users)
from repro.models.recsys import dien, din, dssm, ydnn
from repro.training.optimizer import AdamW, cosine_schedule
from repro.training.trainer import build_train_step, init_state


@dataclass(frozen=True)
class ExperimentConfig:
    world: WorldConfig = WorldConfig(n_users=4000, n_items=600, hist_len=16)
    expose: int = 10  # e of revenue@e (paper: 20 at corpus 4000)
    n_scales: int = 6  # |N_2| = |N_3|
    cascade_steps: int = 250
    reward_steps: int = 600
    batch: int = 64
    seed: int = 0
    # paper split shifts mass from validation (unused offline) to final
    # eval: realized-revenue comparisons need more than a 2.5% slice at
    # mini scale (DESIGN.md §8 deviation)
    split_fracs: tuple = (0.5, 0.05, 0.25, 0.2)
    # paper Table 1 FLOPs keep the budget axis in paper units
    flops: tuple = (13e3, 123e3, 7020e3, 7098e3)


def scaled_stage_specs(cfg: ExperimentConfig) -> tuple[StageSpec, ...]:
    """Paper's chain space with item scales proportional to the corpus.

    The paper uses N2 in 20-37.5% and N3 in 1.5-5% of a 4000-item corpus
    with e=20.  At mini corpora (a few hundred items) 1.5-5% collapses to
    ~expose and the rank stage becomes a no-op (exposing top-e of e
    candidates), which degenerates every chain to the prerank ordering.
    When that happens (max N3 under the paper band < 3x expose) we
    stretch N3 to [expose, 20%] and N2 to [20%, 50%] so the computation
    axis stays meaningful at small scale; corpora large enough to keep
    the paper band non-degenerate use the paper ratios (DESIGN.md §8)."""
    i = cfg.world.n_items
    if 0.05 * i >= 3 * cfg.expose:  # paper band is non-degenerate
        n2_band, n3_band = (0.20, 0.375), (0.015, 0.05)
    else:
        n2_band, n3_band = (0.20, 0.50), (0.015, 0.20)
    n2 = tuple(sorted({int(x) for x in
                       np.linspace(n2_band[0] * i, n2_band[1] * i,
                                   cfg.n_scales)}))
    n3 = tuple(sorted({max(cfg.expose, int(x)) for x in
                       np.linspace(max(cfg.expose, n3_band[0] * i),
                                   n3_band[1] * i, cfg.n_scales)}))
    f_dssm, f_ydnn, f_din, f_dien = cfg.flops
    return (
        StageSpec("recall", (ModelInstance("DSSM", f_dssm, auc=0.525),),
                  (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", f_ydnn, auc=0.581),),
                  n2, 4),
        StageSpec("rank", (ModelInstance("DIN", f_din, auc=0.639),
                           ModelInstance("DIEN", f_dien, auc=0.641)),
                  n3, 4),
    )


@dataclass
class Experiment:
    cfg: ExperimentConfig
    world: World
    split: object
    chains: ActionChainSet
    models: CascadeModels
    clicks_eval: np.ndarray  # (U_eval, I) ground truth
    clicks_reward: np.ndarray  # (U_reward, I)
    revenue_eval: np.ndarray  # (U_eval, J) simulated true revenue
    revenue_reward: np.ndarray  # (U_reward, J)
    ctx_eval: np.ndarray
    ctx_reward: np.ndarray
    reward_params: dict = None
    reward_cfg: RewardModelConfig = None
    history: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Cascade model training
# ---------------------------------------------------------------------------


def _train_model(loss_fn, params, pipe_fn, steps, batch, seed, lr=3e-3):
    opt = AdamW(weight_decay=1e-5)
    step = build_train_step(loss_fn, opt, cosine_schedule(lr, 20, steps),
                            donate=False)
    state = init_state(params, opt)
    rng = np.random.default_rng(seed)
    losses = []
    for t in range(steps):
        b = pipe_fn(rng)
        state, m = step(state, jax.tree_util.tree_map(jnp.asarray, b))
        losses.append(float(m["loss"]))
    return state.params, losses


def train_cascade_models(world: World, users: np.ndarray,
                         cfg: ExperimentConfig) -> CascadeModels:
    w = world.cfg
    n_uf = w.n_user_fields
    user_vocab = n_uf * w.user_field_vocab

    # Recall tower is CATEGORY-ONLY and low-capacity on purpose: the paper's
    # stage quality ladder (DSSM 0.525 < YDNN 0.581 < DIN/DIEN ~0.64 AUC)
    # only emerges at mini scale if the recall model generalizes coarsely
    # instead of memorizing a few hundred item ids.
    dssm_cfg = dssm.DSSMConfig(user_vocab=user_vocab, item_vocab=w.n_items,
                               n_user_fields=n_uf, n_item_fields=1,
                               embed_dim=4, hidden=(16, 8), d_out=4)
    ydnn_cfg = ydnn.YDNNConfig(item_vocab=w.n_items, user_vocab=user_vocab,
                               n_user_fields=n_uf, hist_len=w.hist_len,
                               embed_dim=8, hidden=(48, 24), d_out=12)
    din_cfg = din.DINConfig(item_vocab=w.n_items, cat_vocab=w.n_cats,
                            user_vocab=user_vocab, n_user_fields=n_uf,
                            embed_dim=8, seq_len=w.hist_len,
                            attn_hidden=(16, 8), mlp_hidden=(32, 16))
    dien_cfg = dien.DIENConfig(item_vocab=w.n_items, cat_vocab=w.n_cats,
                               user_vocab=user_vocab, n_user_fields=n_uf,
                               embed_dim=8, seq_len=w.hist_len,
                               attn_hidden=(16, 8), mlp_hidden=(32, 16))
    key = jax.random.PRNGKey(cfg.seed)

    def pipe(rng):
        b = ctr_batch(world, users, rng, cfg.batch)
        b.pop("users")
        return b

    # DSSM: two-tower on (user_fields, category) with BCE
    def dssm_loss(p, b):
        items = jnp.stack([b["item_cat"]], axis=-1)[:, None, :]
        s = dssm.score(p, dssm_cfg, b["user_fields"], items)[:, 0] * 6.0
        y = b["label"]
        return jnp.mean(jnp.maximum(s, 0) - s * y +
                        jnp.log1p(jnp.exp(-jnp.abs(s))))

    dssm_params, _ = _train_model(dssm_loss, dssm.init(key, dssm_cfg), pipe,
                                  cfg.cascade_steps, cfg.batch, cfg.seed + 1)

    def ydnn_loss(p, b):
        s = ydnn.score(p, ydnn_cfg, b["hist_ids"], b["hist_mask"],
                       b["user_fields"], b["item_id"][:, None])[:, 0]
        y = b["label"]
        return jnp.mean(jnp.maximum(s, 0) - s * y +
                        jnp.log1p(jnp.exp(-jnp.abs(s))))

    ydnn_params, _ = _train_model(ydnn_loss, ydnn.init(key, ydnn_cfg), pipe,
                                  cfg.cascade_steps, cfg.batch, cfg.seed + 2)

    # rank models get 2x the steps: they carry the cascade's quality
    # ceiling and are the paper's most accurate (and costly) stage
    din_params, _ = _train_model(
        lambda p, b: din.loss_fn(p, din_cfg, b), din.init(key, din_cfg),
        pipe, 2 * cfg.cascade_steps, cfg.batch, cfg.seed + 3)
    dien_params, _ = _train_model(
        lambda p, b: dien.loss_fn(p, dien_cfg, b), dien.init(key, dien_cfg),
        pipe, 2 * cfg.cascade_steps, cfg.batch, cfg.seed + 4)

    return CascadeModels(dssm_params, dssm_cfg, ydnn_params, ydnn_cfg,
                         din_params, din_cfg, dien_params, dien_cfg)


# ---------------------------------------------------------------------------
# Build the full experiment
# ---------------------------------------------------------------------------


def build_experiment(cfg: ExperimentConfig = ExperimentConfig(),
                     *, verbose: bool = False) -> Experiment:
    log = print if verbose else (lambda *a: None)
    world = build_world(cfg.world)
    split = split_users(world, seed=cfg.seed + 10, fracs=cfg.split_fracs)
    chains = generate_action_chains(scaled_stage_specs(cfg))
    log(f"[exp] world U={cfg.world.n_users} I={cfg.world.n_items} "
        f"J={chains.n_chains}")

    models = train_cascade_models(world, split.cascade_train, cfg)
    log("[exp] cascade models trained")

    rng = np.random.default_rng(cfg.seed + 20)
    out = {}
    for name, users in (("eval", split.final_eval),
                        ("reward", split.reward_train)):
        scores = precompute_stage_scores(models, world, users)
        clicks = world.sample_clicks(
            users, np.tile(np.arange(world.cfg.n_items), (len(users), 1)),
            rng)
        rev = simulate_revenue_matrix(scores, chains, clicks,
                                      expose=cfg.expose)
        out[name] = (scores, clicks, rev)
        log(f"[exp] simulated {name}: users={len(users)} "
            f"mean_rev={rev.mean():.3f}")

    return Experiment(
        cfg=cfg, world=world, split=split, chains=chains, models=models,
        clicks_eval=out["eval"][1], clicks_reward=out["reward"][1],
        revenue_eval=out["eval"][2], revenue_reward=out["reward"][2],
        ctx_eval=world.reward_context(split.final_eval),
        ctx_reward=world.reward_context(split.reward_train),
    )


# ---------------------------------------------------------------------------
# Reward model training (paper §4.2 on simulated chain samples)
# ---------------------------------------------------------------------------


def train_reward_model(exp: Experiment, *, recursive: bool = True,
                       multi_basis: bool = True, steps: int | None = None,
                       seed: int = 0) -> tuple[dict, RewardModelConfig]:
    """Train the personalized reward model on simulated chain revenues.

    Two departures from the seed's (user, chain)-pair sampling, both
    enabled by the cheap vectorized simulator:

    * PER-CHAIN LABEL NORMALIZATION: the model fits the revenue RATIO
      y_uj = rev_uj / mean_u(rev_uj).  The per-chain mean curve (how much
      compute helps on average) is measured exactly from the simulation;
      the network only has to learn per-user DEVIATIONS from it - the
      user heterogeneity GreenFlow allocates on.  The multi-basis head is
      non-negative/monotone by construction, which a ratio target (>= 0,
      centered at 1) respects while a signed residual would not.
      Predictions are de-normalized with the stored ``label_norm``.
    * FULL-ROW BATCHES: each step draws a batch of users and regresses
      ALL J chains per user at once (one ``reward_matrix`` call), so each
      gradient sees every chain's label for the sampled users.
    """
    cfg = exp.cfg
    chains = exp.chains
    rcfg = RewardModelConfig(
        n_stages=chains.n_stages, max_models=2, n_scale_groups=4,
        d_context=exp.ctx_reward.shape[1], d_feature=32, d_hidden=32,
        d_state=16, recursive=recursive, multi_basis=multi_basis)
    params = reward_model_init(jax.random.PRNGKey(seed + 33), rcfg)
    steps = steps or cfg.reward_steps

    rev = exp.revenue_reward  # (U, J)
    mu = chain_label_norm(rev)  # (J,)
    labels = (rev / mu[None, :]).astype(np.float32)
    mo = jnp.asarray(chains.model_onehot)
    sh = jnp.asarray(chains.scale_multihot)

    def loss_fn(p, b):
        pred = reward_matrix(p, rcfg, b["context"], mo, sh)  # (B, J)
        return jnp.mean(jnp.square(pred - b["label"]))

    opt = AdamW(weight_decay=1e-5)
    step = build_train_step(loss_fn, opt,
                            cosine_schedule(3e-3, 20, steps), donate=False)
    state = init_state(params, opt)
    rng = np.random.default_rng(seed + 44)
    n_u = rev.shape[0]
    b_users = max(8, cfg.batch // 4)  # each user row carries all J labels
    for t in range(steps):
        ui = rng.integers(0, n_u, b_users)
        batch = {"context": jnp.asarray(exp.ctx_reward[ui]),
                 "label": jnp.asarray(labels[ui])}
        state, m = step(state, batch)
    out = dict(state.params)
    out["label_norm"] = jnp.asarray(mu)
    return out, rcfg


def predicted_rewards(exp: Experiment, params, rcfg, ctx) -> np.ndarray:
    # chunked: offline scoring stays O(chunk * J) however many users the
    # eval slice carries (bitwise equal to the full-matrix call per row)
    r = reward_matrix_chunked(params, rcfg, ctx,
                              jnp.asarray(exp.chains.model_onehot),
                              jnp.asarray(exp.chains.scale_multihot))
    return np.asarray(denormalize_rewards(params, np.asarray(r)))


def reward_model_metrics(exp: Experiment, params, rcfg) -> dict:
    """Field-RCE (paper Eq. 12; field = rank-stage scale group) + MSE on
    the held-out eval users."""
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)
    true = exp.revenue_eval
    k_rank = exp.chains.n_stages - 1
    groups = exp.chains.scale_multihot[:, k_rank].sum(-1).astype(int)
    fields = np.tile(groups, (true.shape[0], 1)).reshape(-1)
    rce = field_rce(true.reshape(-1), pred.reshape(-1), fields)
    mse = float(np.mean((pred - true) ** 2))
    return {"field_rce": rce, "mse": mse}


# ---------------------------------------------------------------------------
# Method evaluation (paper Fig. 4 / Tables 2-3 protocol)
# ---------------------------------------------------------------------------


def _realized(exp: Experiment, decisions: np.ndarray) -> tuple[float, float]:
    rev = exp.revenue_eval[np.arange(len(decisions)), decisions].sum()
    spend = exp.chains.costs[decisions].sum()
    return float(rev), float(spend)


def budget_at(exp: Experiment, frac: float, n: int | None = None) -> float:
    """Budget at `frac` of the FEASIBLE range [floor, max]: Eq. 3b serves
    every request one chain, so n*min(c) is the spend floor."""
    chains = exp.chains
    n = n if n is not None else exp.revenue_eval.shape[0]
    floor = chains.costs.min() * n
    return float(floor + frac * (chains.costs.max() * n - floor))


def evaluate_methods(exp: Experiment, budgets_frac=(0.3, 0.5, 0.7, 0.9),
                     *, rewards_pred: np.ndarray | None = None,
                     stage_rewards: list | None = None) -> list[dict]:
    """Evaluate all methods at budgets over the feasible [floor, max]."""
    chains = exp.chains
    n = exp.revenue_eval.shape[0]
    rows = []
    for frac in budgets_frac:
        budget = budget_at(exp, frac)
        row = {"budget_frac": frac, "budget_flops": budget}

        # oracle: allocate on TRUE revenue (upper bound)
        lam = dual_bisect(jnp.asarray(exp.revenue_eval),
                          jnp.asarray(chains.costs, jnp.float32), budget)
        dec = np.asarray(allocate(jnp.asarray(exp.revenue_eval),
                                  jnp.asarray(chains.costs, jnp.float32),
                                  lam))
        row["oracle"], row["oracle_spend"] = _realized(exp, dec)

        # GreenFlow: allocate on the reward model's predictions
        if rewards_pred is not None:
            lam = dual_bisect(jnp.asarray(rewards_pred),
                              jnp.asarray(chains.costs, jnp.float32), budget)
            dec = np.asarray(allocate(jnp.asarray(rewards_pred),
                                      jnp.asarray(chains.costs, jnp.float32),
                                      lam))
            row["greenflow"], row["greenflow_spend"] = _realized(exp, dec)

        # EQUAL-DIN / EQUAL-DIEN
        for mname in ("DIN", "DIEN"):
            j = equal_allocation(chains, budget, n, rank_model=mname)
            dec = np.full(n, j, np.int32)
            row[f"equal_{mname.lower()}"], _ = _realized(exp, dec)

        # CRAS-DIN / CRAS-DIEN / CRAS-both
        if stage_rewards is not None:
            for mname in ("DIN", "DIEN", None):
                key = f"cras_{mname.lower()}" if mname else "cras_both"
                spaces = [StageActionSpace.from_chains(chains, k)
                          for k in range(chains.n_stages)]
                dec = cras_allocation(stage_rewards, spaces, chains, budget,
                                      rank_model=mname)
                row[key], _ = _realized(exp, dec)
        rows.append(row)
    return rows


def serve_config(*, small: bool = False) -> ExperimentConfig:
    """The serving demo/benchmark world (launch/serve.py --small flag)."""
    return ExperimentConfig(
        world=WorldConfig(n_users=800 if small else 2000,
                          n_items=200 if small else 400,
                          hist_len=10, seed=11),
        expose=8, n_scales=4,
        cascade_steps=100 if small else 200,
        reward_steps=200 if small else 400, batch=48)


def build_serving_stack(cfg: ExperimentConfig | None = None, *,
                        small: bool = False, cache: bool = True,
                        verbose: bool = False):
    """Experiment + trained reward model + CascadeServer over the eval
    users - the serving universe shared by ``launch/serve.py`` and
    ``benchmarks/bench_serve.py``.  Returns (exp, server, params, rcfg).

    The built experiment (not the reward model - it trains in seconds)
    is pickled under results/cache keyed by every size-relevant field.
    """
    import os
    import pickle

    from repro.cascade.engine import CascadeServer, precompute_stage_scores

    cfg = cfg or serve_config(small=small)
    exp = None
    path = None
    if cache:
        w = cfg.world
        key = (f"serve_u{w.n_users}_i{w.n_items}_h{w.hist_len}"
               f"_ws{w.seed}_s{cfg.seed}_c{cfg.cascade_steps}"
               f"_e{cfg.expose}_ns{cfg.n_scales}_b{cfg.batch}"
               f"_r{cfg.reward_steps}.pkl")
        root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "results", "cache")
        os.makedirs(root, exist_ok=True)
        path = os.path.join(root, key)
        if os.path.exists(path):
            with open(path, "rb") as f:
                exp = pickle.load(f)
    if exp is None:
        exp = build_experiment(cfg, verbose=verbose)
        if path is not None:
            with open(path, "wb") as f:
                pickle.dump(exp, f)
    params, rcfg = train_reward_model(exp)
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=cfg.expose)
    return exp, server, params, rcfg


def cras_stage_rewards(exp: Experiment, ctx_users: str = "eval") -> list:
    """Per-stage independent reward estimates (Yang et al. 2021 setup):
    stage-action value = mean true revenue over chains sharing the action,
    estimated from the REWARD-TRAIN users and applied per-request via a
    nearest-context lookup (an honest, simple per-stage estimator)."""
    chains = exp.chains
    rev_tr = exp.revenue_reward  # (U_tr, J)
    ctx_tr = exp.ctx_reward
    ctx_ev = exp.ctx_eval if ctx_users == "eval" else ctx_tr
    # nearest training user by context (cheap kNN, k=8)
    d = ((ctx_ev[:, None, :] - ctx_tr[None, :, :]) ** 2).sum(-1)
    nn = np.argsort(d, axis=1)[:, :8]  # (U_ev, 8)
    rev_ev_est = rev_tr[nn].mean(axis=1)  # (U_ev, J)

    out = []
    for k in range(chains.n_stages):
        sp = StageActionSpace.from_chains(chains, k)
        cols = []
        for a in range(len(sp.costs)):
            mi, si = sp.actions[a]
            mask = (chains.chain_idx[:, k, 0] == mi) & \
                   (chains.chain_idx[:, k, 1] == si)
            cols.append(rev_ev_est[:, mask].mean(axis=1))
        out.append(jnp.asarray(np.stack(cols, axis=1), jnp.float32))
    return out
