"""bst [arXiv:1905.06874]: embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256 interaction=transformer-seq (Alibaba Behavior Sequence
Transformer).  4M-item id space; tables replicated (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as rc
from repro.configs.base import BATCH, DryRunCell, sds
from repro.models.recsys import bst as model

ARCH_ID = "bst"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
SKIPPED_SHAPES: dict = {}


def full_config() -> model.BSTConfig:
    return model.BSTConfig()  # assigned numbers are the defaults


def smoke_config() -> model.BSTConfig:
    return model.BSTConfig(item_vocab=500, cat_vocab=20, user_vocab=100,
                           n_user_fields=2, embed_dim=8, seq_len=6,
                           n_heads=4, mlp_hidden=(32, 16, 8))


def _batch(cfg, b, with_label=True):
    t = cfg.seq_len - 1  # history slots (target appended in the model)
    batch = {
        "hist_ids": sds((b, t), jnp.int32),
        "hist_cats": sds((b, t), jnp.int32),
        "hist_mask": sds((b, t), jnp.float32),
        "user_fields": sds((b, cfg.n_user_fields), jnp.int32),
        "item_id": sds((b,), jnp.int32),
        "item_cat": sds((b,), jnp.int32),
    }
    specs = {k: P(BATCH, None) if v.ndim == 2 else P(BATCH)
             for k, v in batch.items()}
    if with_label:
        batch["label"] = sds((b,), jnp.float32)
        specs["label"] = P(BATCH)
    return batch, specs


def make_cell(shape: str) -> DryRunCell:
    cfg = full_config()
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    pspec = jax.tree_util.tree_map(lambda _: P(), params)
    info = rc.RECSYS_SHAPES[shape]

    if shape == "train_batch":
        batch, bspec = _batch(cfg, info["batch"])
        return rc.train_cell(
            ARCH_ID, shape,
            loss_fn=lambda p, b: model.loss_fn(p, cfg, b),
            abstract_params=params, param_specs=pspec,
            batch=batch, batch_specs=bspec,
            flops_fwd=info["batch"] * model.flops_per_example(cfg))
    if shape == "retrieval_cand":
        n = info["n_candidates"]
        user, uspec = _batch(cfg, 1, with_label=False)
        user.pop("item_id"), user.pop("item_cat")
        uspec = {k: P(None, None) if user[k].ndim == 2 else P(None)
                 for k in user}

        def fwd(p, u, cid, ccat):
            return model.score_candidates_chunked(p, cfg, u, cid, ccat,
                                                  n_chunks=8)

        return rc.retrieval_cell(
            ARCH_ID, fwd=fwd, abstract_params=params, param_specs=pspec,
            args=(user, sds((n,), jnp.int32), sds((n,), jnp.int32)),
            arg_specs=(uspec, P(BATCH), P(BATCH)),
            flops_fwd=n * model.flops_per_example(cfg))

    b = info["batch"]
    batch, bspec = _batch(cfg, b, with_label=False)

    def fwd(p, bb):
        return model.forward(p, cfg, bb)

    return rc.serve_cell(ARCH_ID, shape, fwd=fwd, abstract_params=params,
                         param_specs=pspec, batch=batch, batch_specs=bspec,
                         flops_fwd=b * model.flops_per_example(cfg))


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return model.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    b, t = 16, cfg.seq_len - 1
    return {
        "hist_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (b, t)), jnp.int32),
        "hist_cats": jnp.asarray(rng.integers(0, cfg.cat_vocab, (b, t)), jnp.int32),
        "hist_mask": jnp.ones((b, t), jnp.float32),
        "user_fields": jnp.asarray(
            rng.integers(0, cfg.user_vocab, (b, cfg.n_user_fields)), jnp.int32),
        "item_id": jnp.asarray(rng.integers(0, cfg.item_vocab, b), jnp.int32),
        "item_cat": jnp.asarray(rng.integers(0, cfg.cat_vocab, b), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
    }


def smoke_loss(params, cfg, batch):
    return model.loss_fn(params, cfg, batch)
