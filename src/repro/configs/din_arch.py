"""din [arXiv:1706.06978] as an ASSIGNED architecture: embed_dim=18
seq_len=100 attn_mlp=80-40 mlp=200-80 interaction=target-attn.

Production-scale id spaces (Alibaba-like): 10M items / 100K categories /
1M user-field rows.  Tables are replicated (they are ~20x smaller than
DLRM's; documented trade-off in DESIGN.md §6).  This model is ALSO the
paper cascade's ranking stage - the GreenFlow action chains select it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as rc
from repro.configs.base import BATCH, DryRunCell, sds
from repro.models.recsys import din as model

ARCH_ID = "din"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
SKIPPED_SHAPES: dict = {}


def full_config() -> model.DINConfig:
    return model.DINConfig(item_vocab=10_000_000, cat_vocab=100_000,
                           user_vocab=1_000_000, n_user_fields=2,
                           embed_dim=18, seq_len=100,
                           attn_hidden=(80, 40), mlp_hidden=(200, 80))


def smoke_config() -> model.DINConfig:
    return model.DINConfig(item_vocab=500, cat_vocab=20, user_vocab=200,
                           n_user_fields=2, embed_dim=8, seq_len=12,
                           attn_hidden=(16, 8), mlp_hidden=(32, 16))


def _pspec(params):
    return jax.tree_util.tree_map(lambda _: P(), params)


def _batch(cfg, b, with_label=True):
    t = cfg.seq_len
    batch = {
        "hist_ids": sds((b, t), jnp.int32),
        "hist_cats": sds((b, t), jnp.int32),
        "hist_mask": sds((b, t), jnp.float32),
        "user_fields": sds((b, cfg.n_user_fields), jnp.int32),
        "item_id": sds((b,), jnp.int32),
        "item_cat": sds((b,), jnp.int32),
    }
    specs = {k: P(BATCH, None) if v.ndim == 2 else P(BATCH)
             for k, v in batch.items()}
    if with_label:
        batch["label"] = sds((b,), jnp.float32)
        specs["label"] = P(BATCH)
    return batch, specs


def make_cell(shape: str) -> DryRunCell:
    cfg = full_config()
    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    pspec = _pspec(params)
    info = rc.RECSYS_SHAPES[shape]

    if shape == "train_batch":
        batch, bspec = _batch(cfg, info["batch"])
        return rc.train_cell(
            ARCH_ID, shape,
            loss_fn=lambda p, b: model.loss_fn(p, cfg, b),
            abstract_params=params, param_specs=pspec,
            batch=batch, batch_specs=bspec,
            flops_fwd=info["batch"] * model.flops_per_item(cfg))
    if shape == "retrieval_cand":
        n = info["n_candidates"]
        user, uspec = _batch(cfg, 1, with_label=False)
        user.pop("item_id"), user.pop("item_cat")
        uspec.pop("item_id"), uspec.pop("item_cat")
        uspec = {k: P(None, None) if user[k].ndim == 2 else P(None)
                 for k in user}

        def fwd(p, u, cid, ccat):
            return model.score_candidates_chunked(p, cfg, u, cid, ccat,
                                                  n_chunks=16)

        return rc.retrieval_cell(
            ARCH_ID, fwd=fwd, abstract_params=params, param_specs=pspec,
            args=(user, sds((n,), jnp.int32), sds((n,), jnp.int32)),
            arg_specs=(uspec, P(BATCH), P(BATCH)),
            flops_fwd=n * model.flops_per_item(cfg))

    b = info["batch"]
    batch, bspec = _batch(cfg, b, with_label=False)

    def fwd(p, bb):
        return model.forward(p, cfg, bb)

    return rc.serve_cell(ARCH_ID, shape, fwd=fwd, abstract_params=params,
                         param_specs=pspec, batch=batch, batch_specs=bspec,
                         flops_fwd=b * model.flops_per_item(cfg))


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return model.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    b, t = 16, cfg.seq_len
    return {
        "hist_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, (b, t)), jnp.int32),
        "hist_cats": jnp.asarray(rng.integers(0, cfg.cat_vocab, (b, t)), jnp.int32),
        "hist_mask": jnp.ones((b, t), jnp.float32),
        "user_fields": jnp.asarray(
            rng.integers(0, cfg.user_vocab, (b, cfg.n_user_fields)), jnp.int32),
        "item_id": jnp.asarray(rng.integers(0, cfg.item_vocab, b), jnp.int32),
        "item_cat": jnp.asarray(rng.integers(0, cfg.cat_vocab, b), jnp.int32),
        "label": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
    }


def smoke_loss(params, cfg, batch):
    return model.loss_fn(params, cfg, batch)
