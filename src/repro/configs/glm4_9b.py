"""glm4-9b [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552; RoPE over half
the head dim (partial rotary), SwiGLU, RMSNorm.
"""
from __future__ import annotations

import numpy as np

from repro.configs import base
from repro.models import lm

ARCH_ID = "glm4-9b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPPED_SHAPES = {
    "long_500k": "pure full-attention stack (no sub-quadratic path); "
                 "skipped per brief - see DESIGN.md §5",
}


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID, n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
        d_head=128, d_ff=13696, vocab=151552, padded_vocab=151552,
        rope_theta=10_000.0, rope_fraction=0.5,
        tie_embeddings=False, fsdp=True, attn_chunk_q=1024,
        sequence_parallel=True,
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=128, padded_vocab=128,
        rope_fraction=0.5, tie_embeddings=False, dtype="float32",
        remat=False, fsdp=False,
    )


def make_cell(shape: str) -> base.DryRunCell:
    return base.lm_make_cell(ARCH_ID, full_config(), shape)


def init_smoke(key, cfg):
    return lm.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    return base.lm_smoke_batch(rng, cfg)


def smoke_loss(params, cfg, batch):
    return lm.loss_fn(params, cfg, batch)
