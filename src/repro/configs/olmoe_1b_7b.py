"""olmoe-1b-7b [arXiv:2409.02060].

16L d_model=2048 16H (kv=16, MHA) d_ff=1024/expert vocab=50304,
MoE 64 experts top-8, QK-norm.  ~6.9B total / ~1.3B active.
"""
from __future__ import annotations

import numpy as np

from repro.configs import base
from repro.models import lm

ARCH_ID = "olmoe-1b-7b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPPED_SHAPES = {
    "long_500k": "pure full-attention stack (no sub-quadratic path); "
                 "skipped per brief - see DESIGN.md §5",
}


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID, n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=1024, vocab=50304, padded_vocab=50432,
        rope_theta=10_000.0, qk_norm=True,
        moe=lm.MoEConfig(n_experts=64, top_k=8, d_expert=1024),
        tie_embeddings=False, fsdp=True, attn_chunk_q=1024,
        sequence_parallel=True,
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=32, vocab=128, padded_vocab=128,
        qk_norm=True, moe=lm.MoEConfig(n_experts=8, top_k=2, d_expert=32),
        tie_embeddings=False, dtype="float32", remat=False, fsdp=False,
    )


def make_cell(shape: str) -> base.DryRunCell:
    return base.lm_make_cell(ARCH_ID, full_config(), shape)


def init_smoke(key, cfg):
    return lm.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    return base.lm_smoke_batch(rng, cfg)


def smoke_loss(params, cfg, batch):
    return lm.loss_fn(params, cfg, batch)
