"""Config/registry layer: every assigned architecture exposes the same
surface so the launcher, dry-run, smoke tests and benchmarks are generic.

Per-arch module contract:
    ARCH_ID: str;  FAMILY: "lm"|"gnn"|"recsys";  SHAPES: tuple[str,...]
    SKIPPED_SHAPES: dict[shape, reason]      (e.g. long_500k on full attn)
    full_config() / smoke_config()           model config objects
    make_cell(shape, multi_pod=False) -> DryRunCell
    smoke_batch(rng, cfg) -> batch dict      (reduced shapes, CPU-sized)
    init_smoke(key, cfg) / smoke_loss(params, cfg, batch)

A DryRunCell is everything ``launch/dryrun.py`` needs to lower+compile one
(arch x shape x mesh) combination: a pure ``fn``, ShapeDtypeStruct inputs,
and PartitionSpec trees for inputs/outputs.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, init_state

BATCH = ("pod", "data")  # logical batch axes (multi-pod collapses onto both)


@dataclass
class DryRunCell:
    arch_id: str
    shape_name: str
    kind: str  # train | prefill | decode | serve | retrieval
    fn: Callable  # pure; positional args mirror arg_specs
    arg_specs: tuple  # pytree of ShapeDtypeStruct per positional arg
    in_shardings: tuple  # pytree of PartitionSpec per positional arg
    out_shardings: Any = None  # None -> let GSPMD choose
    donate: tuple = ()  # positional indices to donate
    meta: dict = field(default_factory=dict)  # model_flops etc.
    # XLA cost_analysis counts a while-loop body ONCE.  Cells whose main
    # compute sits in a lax.scan provide (variant_fn, trips, period):
    # lowering variant_fn(period) and variant_fn(2*period) yields the
    # per-period delta, and corrected = m(p) + (trips/p - 1) * delta.
    variant_fn: Callable | None = None
    loop_trips: int = 0  # e.g. n_layers
    loop_period: int = 1  # e.g. len(window_pattern) for gemma2


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def replicated_like(tree):
    return jax.tree_util.tree_map(lambda _: P(), tree)


def eval_shape_of(fn, *args):
    return jax.eval_shape(fn, *args)


# ---------------------------------------------------------------------------
# LM family cells (shared by the five assigned LM archs)
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32768, batch=128, kind="decode"),
    "long_500k": dict(seq=524288, batch=1, kind="decode"),
}


def _adam_specs(param_specs):
    """AdamState(step, mu, nu) sharded like the params."""
    from repro.training.optimizer import AdamState
    return AdamState(step=P(), mu=param_specs,
                     nu=jax.tree_util.tree_map(lambda s: s, param_specs))


def lm_state_specs(cfg: lm.LMConfig):
    pspec = lm.param_shardings(cfg)
    return TrainState(step=P(), params=pspec, opt_state=_adam_specs(pspec))


def lm_abstract_state(cfg: lm.LMConfig):
    """TrainState of ShapeDtypeStructs without allocating anything."""
    opt = AdamW()
    params = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
    state = jax.eval_shape(lambda p: init_state(p, opt), params)
    return state


# per-arch gradient-accumulation microbatches for train_4k: sized so the
# per-device live set (saved layer carries + logits + attention blocks)
# fits v5e's 16 GB HBM.  Python-loop accumulation -> exact HLO flop counts.
LM_TRAIN_MICRO = {
    "granite-moe-1b-a400m": 2,
    "olmoe-1b-7b": 4,
    "glm4-9b": 8,
    "gemma2-2b": 4,
    "minicpm-2b": 8,
}


def lm_train_cell(arch_id: str, cfg: lm.LMConfig, shape_name: str,
                  *, n_micro: int | None = None) -> DryRunCell:
    info = LM_SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    opt = AdamW(weight_decay=0.1)
    if n_micro is None:
        n_micro = LM_TRAIN_MICRO.get(arch_id, 1)

    pspec = lm.param_shardings(cfg)

    def pin(grads):
        # ZeRO-2-style: keep accumulated grads in the params' (FSDP x TP)
        # layout - forces reduce-scatter instead of replicated all-reduce
        # and caps the fp32 grad buffer at params_bytes / n_shards.
        from repro.distributed.sharding import current_mesh
        if current_mesh() is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, pspec)

    def step(state: TrainState, batch: dict):
        if n_micro == 1:
            l, grads = jax.value_and_grad(
                lambda p: lm.loss_fn(p, cfg, batch))(state.params)
            grads = pin(grads)
        else:
            mb_rows = b // n_micro
            l, grads = 0.0, None
            for m in range(n_micro):
                mb = {k: jax.lax.dynamic_slice_in_dim(v, m * mb_rows,
                                                      mb_rows, axis=0)
                      for k, v in batch.items()}
                lm_, g = jax.value_and_grad(
                    lambda p: lm.loss_fn(p, cfg, mb))(state.params)
                g = pin(g)
                l = l + lm_ / n_micro
                grads = g if grads is None else jax.tree_util.tree_map(
                    jnp.add, grads, g)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, 3e-4)
        return TrainState(state.step + 1, new_params, new_opt), l

    state = lm_abstract_state(cfg)
    batch = {
        "tokens": sds((b, s), jnp.int32),
        "targets": sds((b, s), jnp.int32),
        "mask": sds((b, s), jnp.float32),
    }
    batch_specs = {k: P(BATCH, None) for k in batch}
    n_tokens = b * s
    return DryRunCell(
        arch_id=arch_id, shape_name=shape_name, kind="train",
        fn=step, arg_specs=(state, batch),
        in_shardings=(lm_state_specs(cfg), batch_specs),
        donate=(0,),
        meta={"model_flops": 6.0 * cfg.n_active_params() * n_tokens,
              "n_tokens": n_tokens, "n_microbatches": n_micro},
    )


def lm_prefill_cell(arch_id: str, cfg: lm.LMConfig, shape_name: str) -> DryRunCell:
    import dataclasses
    info = LM_SHAPES[shape_name]
    b, s = info["batch"], info["seq"]
    if cfg.attn_chunk_q:
        cfg = dataclasses.replace(cfg, attn_chunk_q=2048)

    def step(params, tokens):
        return lm.prefill(params, cfg, tokens, max_len=s)

    params = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
    # the produced cache is SEQ-sharded over 'model' - the exact layout
    # decode consumes (SPerf iteration 4: stacking the cache unsharded on
    # seq left minicpm/glm4 prefill temps at 52/35 GB per device)
    cache_spec = {"k": P(None, BATCH, "model", None, None),
                  "v": P(None, BATCH, "model", None, None),
                  "length": P()}
    return DryRunCell(
        arch_id=arch_id, shape_name=shape_name, kind="prefill",
        fn=step,
        arg_specs=(params, sds((b, s), jnp.int32)),
        in_shardings=(lm.param_shardings(cfg), P(BATCH, None)),
        out_shardings=(P(BATCH, "model"), cache_spec),
        meta={"model_flops": 2.0 * cfg.n_active_params() * b * s,
              "n_tokens": b * s},
    )


def lm_decode_cell(arch_id: str, cfg: lm.LMConfig, shape_name: str,
                   *, seq_sharded: bool = False) -> DryRunCell:
    info = LM_SHAPES[shape_name]
    b, s = info["batch"], info["seq"]

    def step(params, token, cache):
        return lm.decode_step(params, cfg, token, cache)

    params = jax.eval_shape(lambda k: lm.init(k, cfg), jax.random.PRNGKey(0))
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, b, s))
    # batch=1 cells (long-context decode) cannot shard the batch dim
    bspec = BATCH if b >= 32 else None
    seq = "model" if seq_sharded else None
    cache_spec = {"k": P(None, bspec, seq, None, None),
                  "v": P(None, bspec, seq, None, None),
                  "length": P()}
    return DryRunCell(
        arch_id=arch_id, shape_name=shape_name, kind="decode",
        fn=step,
        arg_specs=(params, sds((b,), jnp.int32), cache),
        in_shardings=(lm.param_shardings(cfg), P(bspec), cache_spec),
        out_shardings=((P(bspec, "model"), cache_spec)),
        donate=(2,),
        meta={"model_flops": 2.0 * cfg.n_active_params() * b
              + 2.0 * cfg.n_layers * b * s * cfg.n_kv_heads * cfg.d_head * 2,
              "n_tokens": b, "kv_len": s},
    )


def _lm_cell_raw(arch_id: str, cfg: lm.LMConfig, shape_name: str,
                 *, long_seq_sharded: bool = True) -> DryRunCell:
    kind = LM_SHAPES[shape_name]["kind"]
    if kind == "train":
        return lm_train_cell(arch_id, cfg, shape_name)
    if kind == "prefill":
        return lm_prefill_cell(arch_id, cfg, shape_name)
    # KV caches are sequence-sharded over 'model' for every decode shape:
    # kv_heads < 16 on all five archs, so head-sharding is impossible and
    # batch-only sharding leaves caches unfit (e.g. minicpm decode_32k:
    # 96 GB/device) - SPerf iteration 2.
    return lm_decode_cell(arch_id, cfg, shape_name,
                          seq_sharded=long_seq_sharded)


def lm_make_cell(arch_id: str, cfg: lm.LMConfig, shape_name: str,
                 *, long_seq_sharded: bool = True) -> DryRunCell:
    import dataclasses

    cell = _lm_cell_raw(arch_id, cfg, shape_name,
                        long_seq_sharded=long_seq_sharded)
    period = len(cfg.window_pattern) if cfg.window_pattern else 1

    def variant(n_layers: int) -> DryRunCell:
        # fully unrolled so XLA's cost analysis counts every layer body
        vcfg = dataclasses.replace(cfg, n_layers=n_layers,
                                   scan_unroll=n_layers)
        return _lm_cell_raw(arch_id, vcfg, shape_name,
                            long_seq_sharded=long_seq_sharded)

    cell.variant_fn = variant
    cell.loop_trips = cfg.n_layers
    cell.loop_period = period
    return cell


# LM smoke helpers ----------------------------------------------------------


def lm_smoke_batch(rng: np.random.Generator, cfg: lm.LMConfig, *,
                   batch: int = 2, seq: int = 16) -> dict:
    toks = rng.integers(0, cfg.vocab, size=(batch, seq + 1))
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "targets": jnp.asarray(toks[:, 1:], jnp.int32),
            "mask": jnp.ones((batch, seq), jnp.float32)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "granite-moe-1b-a400m", "olmoe-1b-7b", "glm4-9b", "gemma2-2b",
    "minicpm-2b", "schnet", "dlrm-rm2", "din", "xdeepfm", "bst",
    "greenflow-cascade",
)

_MODULES = {
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "glm4-9b": "repro.configs.glm4_9b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "schnet": "repro.configs.schnet",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "din": "repro.configs.din_arch",
    "xdeepfm": "repro.configs.xdeepfm_arch",
    "bst": "repro.configs.bst_arch",
    "greenflow-cascade": "repro.configs.greenflow_cascade",
}


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id])


def all_cells(arch_id: str):
    """Yield (shape_name, cell_or_skip_reason) for every assigned shape."""
    mod = get_arch(arch_id)
    for shape in mod.SHAPES:
        if shape in getattr(mod, "SKIPPED_SHAPES", {}):
            yield shape, mod.SKIPPED_SHAPES[shape]
        else:
            yield shape, mod.make_cell(shape)
