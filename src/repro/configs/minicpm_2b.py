"""minicpm-2b [arXiv:2404.06395] (llama-like + mup-ish scaling).

40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753; WSD schedule
(training/optimizer.wsd_schedule - noted per assignment), embedding scale
12.0, depth-scaled residuals 1.4/sqrt(40), logits divided by
d_model/dim_base(=256).

NOTE 36 heads do not divide the 16-way 'model' axis; GSPMD pads the head
dim (36 -> 48 partitions-worth).  Sequence-sharded attention (the gemma2
fix) was MEASURED WORSE for this arch's train cell (dominant term
19.1 -> 21.6 s, EXPERIMENTS.md SPerf iter 1b - refuted) because with MHA
(kv=36) the replicated KV outweighs the padding saving; head-sharding is
kept.
"""
from __future__ import annotations

import math

import numpy as np

from repro.configs import base
from repro.models import lm

ARCH_ID = "minicpm-2b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPPED_SHAPES = {
    "long_500k": "pure full-attention stack (no sub-quadratic path); "
                 "skipped per brief - see DESIGN.md §5",
}

WSD = dict(peak=1e-2, warmup=2000, stable=200_000, decay=20_000)


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID, n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
        d_head=64, d_ff=5760, vocab=122753, padded_vocab=122880,
        rope_theta=10_000.0,
        embed_scale=12.0, residual_scale=1.4 / math.sqrt(40.0),
        logit_divisor=2304.0 / 256.0,
        tie_embeddings=True, fsdp=True, attn_chunk_q=1024,
        sequence_parallel=True,
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=72, n_heads=6,
        n_kv_heads=6, d_head=12, d_ff=144, vocab=128, padded_vocab=128,
        embed_scale=12.0, residual_scale=1.4 / math.sqrt(2.0),
        logit_divisor=72.0 / 16.0, dtype="float32", remat=False, fsdp=False,
    )


def make_cell(shape: str) -> base.DryRunCell:
    return base.lm_make_cell(ARCH_ID, full_config(), shape)


def init_smoke(key, cfg):
    return lm.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    return base.lm_smoke_batch(rng, cfg)


def smoke_loss(params, cfg, batch):
    return lm.loss_fn(params, cfg, batch)
