"""schnet [arXiv:1706.08566]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10.

Four assigned graph regimes (see DESIGN.md §5): full-batch small (Cora-
sized), sampled minibatch on a Reddit-sized graph (real fanout sampler in
models/gnn/sampler.py feeding PADDED static shapes), full-batch large
(ogbn-products-sized), and batched small molecules.  Edge arrays shard
over every mesh axis; nodes stay replicated (edge-parallel message
passing, segment_sum + GSPMD partial-sum all-reduce).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.configs.base import DryRunCell, sds
from repro.models.gnn import schnet as model
from repro.models.gnn.sampler import budget_for
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, init_state

ARCH_ID = "schnet"
FAMILY = "gnn"
SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
SKIPPED_SHAPES: dict = {}

EDGE_AXES = ("pod", "data", "model")  # shard edges over the whole mesh

GRAPH_SHAPES = {
    # name: (n_nodes, n_edges, d_feat, n_out, task, n_graphs)
    "full_graph_sm": (2708, 10556, 1433, 7, "node_class", None),
    "ogb_products": (2_449_029, 61_859_140, 100, 47, "node_class", None),
    "molecule": (30 * 128, 64 * 128, 0, 1, "graph_reg", 128),
}
MINIBATCH = dict(seeds=1024, fanout=(15, 10), d_feat=602, n_out=41)


def full_config(shape: str = "molecule") -> model.SchNetConfig:
    if shape == "minibatch_lg":
        return model.SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300,
                                  cutoff=10.0, d_feat=MINIBATCH["d_feat"],
                                  n_out=MINIBATCH["n_out"], task="node_class")
    n_nodes, n_edges, d_feat, n_out, task, _ = GRAPH_SHAPES[shape]
    return model.SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300,
                              cutoff=10.0, d_feat=d_feat, n_out=n_out,
                              task=task)


def smoke_config() -> model.SchNetConfig:
    return model.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=24,
                              cutoff=10.0, d_feat=0, n_out=1,
                              task="graph_reg")


def _batch_specs(cfg: model.SchNetConfig, n_nodes, n_edges, n_graphs):
    batch = {
        "nodes": sds((n_nodes, cfg.d_feat) if cfg.d_feat else (n_nodes,),
                     jnp.float32 if cfg.d_feat else jnp.int32),
        "src": sds((n_edges,), jnp.int32),
        "dst": sds((n_edges,), jnp.int32),
        "dist": sds((n_edges,), jnp.float32),
        "edge_mask": sds((n_edges,), jnp.float32),
    }
    shard = {
        "nodes": P(None, None) if cfg.d_feat else P(None),
        "src": P(EDGE_AXES), "dst": P(EDGE_AXES),
        "dist": P(EDGE_AXES), "edge_mask": P(EDGE_AXES),
    }
    if cfg.task == "graph_reg":
        batch["graph_ids"] = sds((n_nodes,), jnp.int32)
        batch["n_graphs"] = n_graphs
        batch["target"] = sds((n_graphs,), jnp.float32)
        shard["graph_ids"] = P(None)
        shard["n_graphs"] = None
        shard["target"] = P(None)
    else:
        batch["target"] = sds((n_nodes,), jnp.int32)
        batch["node_mask"] = sds((n_nodes,), jnp.float32)
        shard["target"] = P(None)
        shard["node_mask"] = P(None)
    return batch, shard


def make_cell(shape: str) -> DryRunCell:
    if shape == "minibatch_lg":
        cfg = full_config(shape)
        n_nodes, n_edges = budget_for(MINIBATCH["seeds"], MINIBATCH["fanout"])
        n_graphs = None
    else:
        n_nodes, n_edges, _, _, _, n_graphs = GRAPH_SHAPES[shape]
        cfg = full_config(shape)
    # pad the edge arrays so they shard evenly over the full 512-chip mesh
    # (padding edges carry edge_mask=0 in the pipeline; zero messages)
    n_edges = ((n_edges + 511) // 512) * 512

    opt = AdamW(weight_decay=0.0)

    def step(state: TrainState, batch: dict):
        l, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, batch))(state.params)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, 1e-3)
        return TrainState(state.step + 1, new_params, new_opt), l

    params = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    state = jax.eval_shape(lambda p: init_state(p, opt), params)
    pspec = jax.tree_util.tree_map(lambda _: P(), params)
    sspec = TrainState(step=P(), params=pspec,
                       opt_state=base._adam_specs(pspec))
    batch, bspec = _batch_specs(cfg, n_nodes, n_edges, n_graphs)
    static_ng = batch.pop("n_graphs", None)
    bspec.pop("n_graphs", None)
    if static_ng is not None:
        step_fn = lambda s, b: step(s, dict(b, n_graphs=static_ng))
    else:
        step_fn = step

    flops = (n_edges * model.flops_per_edge(cfg)
             + n_nodes * model.flops_per_node(cfg)) * 3.0  # fwd+bwd
    return DryRunCell(
        arch_id=ARCH_ID, shape_name=shape, kind="train",
        fn=step_fn, arg_specs=(state, batch),
        in_shardings=(sspec, bspec), donate=(0,),
        meta={"model_flops": flops, "n_edges": n_edges, "n_nodes": n_nodes},
    )


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return model.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    n, e, g = 40, 80, 4
    return {
        "nodes": jnp.asarray(rng.integers(0, 10, n), jnp.int32),
        "src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "dist": jnp.asarray(rng.uniform(0.5, 9.0, e), jnp.float32),
        "edge_mask": jnp.ones(e, jnp.float32),
        "graph_ids": jnp.asarray(np.repeat(np.arange(g), n // g), jnp.int32),
        "n_graphs": g,
        "target": jnp.asarray(rng.normal(size=g), jnp.float32),
    }


def smoke_loss(params, cfg, batch):
    return model.loss_fn(params, cfg, batch)
