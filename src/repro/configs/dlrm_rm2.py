"""dlrm-rm2 [arXiv:1906.00091]: n_dense=13 n_sparse=26 embed_dim=64
bot=13-512-256-64 top=512-512-256-1 interaction=dot.  Criteo-scale tables
(~88M rows), row-sharded over 'model' with ONE stacked lookup + psum.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as rc
from repro.configs.base import BATCH, DryRunCell, sds
from repro.distributed.sharding import current_mesh
from repro.models.recsys import dlrm as model

ARCH_ID = "dlrm-rm2"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
SKIPPED_SHAPES: dict = {}

PAD_TO = 1024  # rows pad so 512-way (model x pod x data) sharding divides
N_ITEM_FIELDS = 4  # trailing sparse fields swapped per retrieval candidate


def full_config() -> model.DLRMConfig:
    return model.DLRMConfig()


def smoke_config() -> model.DLRMConfig:
    return model.DLRMConfig(vocab_sizes=tuple([64] * 26), embed_dim=8,
                            bot_mlp=(32, 16, 8), top_mlp=(64, 32, 1),
                            top_pad=512)


def _abstract(cfg):
    return jax.eval_shape(
        lambda k: model.init(k, cfg, pad_vocab_to=PAD_TO),
        jax.random.PRNGKey(0))


def _pspec(params):
    spec = jax.tree_util.tree_map(lambda _: P(), params)
    spec["tables"]["stacked"] = P(("model", "pod", "data"), None)
    return spec


def _batch(cfg, b):
    batch = {"dense": sds((b, cfg.n_dense), jnp.float32),
             "sparse": sds((b, cfg.n_sparse), jnp.int32),
             "label": sds((b,), jnp.float32)}
    specs = {"dense": P(BATCH, None), "sparse": P(BATCH, None),
             "label": P(BATCH)}
    return batch, specs


def _hybrid_train_cell(cfg, params, pspec, batch, bspec, b) -> DryRunCell:
    """DLRM train with the industry-standard HYBRID optimizer: stateless
    SGD on the embedding table (no mu/nu -> no 2x22GB optimizer state, no
    3x full-table HBM sweeps per step) + AdamW on the dense MLPs.
    §Perf iteration 3 - see EXPERIMENTS.md (baseline: plain AdamW on
    everything, results/dryrun_baseline)."""
    import jax
    from repro.configs.base import _adam_specs
    from repro.training.optimizer import AdamW
    from repro.training.trainer import TrainState

    opt = AdamW(weight_decay=0.0)

    def split(p):
        dense = {k: v for k, v in p.items() if k != "tables"}
        return p["tables"], dense

    def step(state: TrainState, bb: dict):
        l, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, bb, current_mesh()))(state.params)
        g_tab, g_dense = split(grads)
        p_tab, p_dense = split(state.params)
        # stateless SGD for the table (grads arrive bf16 from the wire)
        new_tab = jax.tree_util.tree_map(
            lambda p, g: (p - 0.04 * g.astype(p.dtype)).astype(p.dtype),
            p_tab, g_tab)
        new_dense, new_opt = opt.update(g_dense, state.opt_state,
                                        p_dense, 1e-3)
        new_params = dict(new_dense, tables=new_tab)
        return TrainState(state.step + 1, new_params, new_opt), l

    dense_params = {k: v for k, v in params.items() if k != "tables"}
    state = jax.eval_shape(
        lambda dp: TrainState(jnp.zeros((), jnp.int32),
                              dict(dp[0], tables=dp[1]),
                              AdamW().init(dp[0])),
        (dense_params, params["tables"]))
    dense_spec = {k: v for k, v in pspec.items() if k != "tables"}
    sspec = TrainState(step=P(), params=pspec,
                       opt_state=_adam_specs(dense_spec))
    return DryRunCell(
        arch_id=ARCH_ID, shape_name="train_batch", kind="train",
        fn=step, arg_specs=(state, batch), in_shardings=(sspec, bspec),
        donate=(0,),
        meta={"model_flops": 3.0 * b * model.flops_per_example(cfg),
              "optimizer": "hybrid sgd(emb)+adamw(dense), bf16 wire"},
    )


def make_cell(shape: str) -> DryRunCell:
    cfg = full_config()
    params = _abstract(cfg)
    pspec = _pspec(params)
    info = rc.RECSYS_SHAPES[shape]

    if shape == "train_batch":
        batch, bspec = _batch(cfg, info["batch"])
        return _hybrid_train_cell(cfg, params, pspec, batch, bspec,
                                  info["batch"])
    if shape == "retrieval_cand":
        n = info["n_candidates"]
        user = {"dense": sds((1, cfg.n_dense), jnp.float32),
                "sparse": sds((1, cfg.n_sparse), jnp.int32)}
        uspec = {"dense": P(None, None), "sparse": P(None, None)}
        cand = sds((n, N_ITEM_FIELDS), jnp.int32)

        def fwd(p, u, c):
            return model.retrieval_forward(p, cfg, u, c, current_mesh())

        return rc.retrieval_cell(
            ARCH_ID, fwd=fwd, abstract_params=params, param_specs=pspec,
            args=(user, cand), arg_specs=(uspec, P(BATCH, None)),
            flops_fwd=n * model.flops_per_example(cfg))

    b = info["batch"]
    batch, bspec = _batch(cfg, b)
    batch.pop("label"), bspec.pop("label")

    def fwd(p, bb):
        return model.forward(p, cfg, bb, current_mesh())

    return rc.serve_cell(ARCH_ID, shape, fwd=fwd, abstract_params=params,
                         param_specs=pspec, batch=batch, batch_specs=bspec,
                         flops_fwd=b * model.flops_per_example(cfg))


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return model.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    b = 16
    return {"dense": jnp.asarray(rng.normal(size=(b, cfg.n_dense)), jnp.float32),
            "sparse": jnp.asarray(rng.integers(0, 64, (b, cfg.n_sparse)),
                                  jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, b), jnp.float32)}


def smoke_loss(params, cfg, batch):
    return model.loss_fn(params, cfg, batch)
