"""Per-architecture configs + registry (see base.ARCH_IDS)."""
from repro.configs.base import ARCH_IDS, all_cells, get_arch

__all__ = ["ARCH_IDS", "all_cells", "get_arch"]
