"""gemma2-2b [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4, d_head=256) d_ff=9216 vocab=256000;
local(4096)/global alternating attention, attn softcap 50 / final softcap
30, GeGLU, zero-centered RMSNorm, sandwich norms, embeddings scaled by
sqrt(d_model).

long_500k RUNS for this arch (hybrid local/global): global-layer KV is
sequence-sharded over 'model', local layers are window-bounded via the
mask (see DESIGN.md §5).
"""
from __future__ import annotations

import math

import numpy as np

from repro.configs import base
from repro.models import lm

ARCH_ID = "gemma2-2b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPPED_SHAPES: dict = {}


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID, n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
        d_head=256, d_ff=9216, vocab=256000, padded_vocab=256000,
        rope_theta=10_000.0,
        window_pattern=(4096, -1),  # local, global, local, ...
        attn_softcap=50.0, final_softcap=30.0,
        sandwich_norm=True, zero_centered_norm=True, act="gelu",
        embed_scale=math.sqrt(2304.0), query_scale=1.0 / math.sqrt(256.0),
        tie_embeddings=True, fsdp=True, attn_chunk_q=1024,
        sequence_parallel=True, attn_shard="seq",
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=128, padded_vocab=128,
        window_pattern=(8, -1), attn_softcap=50.0, final_softcap=30.0,
        sandwich_norm=True, zero_centered_norm=True, act="gelu",
        embed_scale=8.0, dtype="float32", remat=False, fsdp=False,
    )


def make_cell(shape: str) -> base.DryRunCell:
    return base.lm_make_cell(ARCH_ID, full_config(), shape)


def init_smoke(key, cfg):
    return lm.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    return base.lm_smoke_batch(rng, cfg)


def smoke_loss(params, cfg, batch):
    return lm.loss_fn(params, cfg, batch)
