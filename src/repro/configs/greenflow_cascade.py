"""greenflow-cascade: the PAPER'S OWN system as dry-run cells.

Four serving/nearline programs (these are what actually runs in front of
a production RS, and what the roofline section analyses for the paper's
technique itself):

  reward_serve  - online module: reward_matrix over B=4096 requests x
                  J=128 action chains, then the Eq. 10 argmax decision;
  nearline_dual - nearline module: L=200 dual-descent steps over a
                  64K-request window (Algorithm 1);
  reward_train  - reward-model train step (B=8192, AdamW);
  rank_serve    - the cascade's ranking stage under allocation:
                  B=1024 requests x n3=200 candidates through DIN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import BATCH, DryRunCell, _adam_specs, sds
from repro.core.action_chain import generate_action_chains, paper_stage_specs
from repro.core.primal_dual import allocate, dual_descent
from repro.core.reward_model import (RewardModelConfig, reward_loss,
                                     reward_matrix, reward_model_init)
from repro.models.recsys import din as din_model
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, init_state

ARCH_ID = "greenflow-cascade"
FAMILY = "recsys"
SHAPES = ("reward_serve", "nearline_dual", "reward_train", "rank_serve")
SKIPPED_SHAPES: dict = {}

D_CONTEXT = 32
N_REQ_SERVE = 4096
N_REQ_NEARLINE = 65_536
N_REQ_TRAIN = 8192
RANK_BATCH = 1024
RANK_CANDS = 200


def full_config() -> RewardModelConfig:
    chains = generate_action_chains(paper_stage_specs())
    return RewardModelConfig(
        n_stages=chains.n_stages, max_models=2, n_scale_groups=4,
        d_context=D_CONTEXT, d_feature=64, d_hidden=64, d_state=32)


def smoke_config() -> RewardModelConfig:
    return RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=8, d_feature=16, d_hidden=16,
                             d_state=8)


def _chains():
    return generate_action_chains(paper_stage_specs())


def make_cell(shape: str) -> DryRunCell:
    cfg = full_config()
    chains = _chains()
    j = chains.n_chains
    params = jax.eval_shape(
        lambda k: reward_model_init(k, cfg), jax.random.PRNGKey(0))
    pspec = jax.tree_util.tree_map(lambda _: P(), params)
    chain_mo = jnp.asarray(chains.model_onehot)
    chain_sh = jnp.asarray(chains.scale_multihot)
    costs32 = jnp.asarray(chains.costs, jnp.float32)

    if shape == "reward_serve":
        def fn(p, ctx, lam):
            r = reward_matrix(p, cfg, ctx, chain_mo, chain_sh)
            return allocate(r, costs32, lam), r

        return DryRunCell(
            arch_id=ARCH_ID, shape_name=shape, kind="serve", fn=fn,
            arg_specs=(params, sds((N_REQ_SERVE, D_CONTEXT), jnp.float32),
                       sds((), jnp.float32)),
            in_shardings=(pspec, P(BATCH, None), P()),
            out_shardings=(P(BATCH), P(BATCH, None)),
            meta={"model_flops": N_REQ_SERVE * j * cfg.n_stages
                  * 2.0 * (cfg.d_hidden * (cfg.d_state + cfg.d_feature + 8)
                           + cfg.d_hidden * cfg.d_hidden)},
        )

    if shape == "nearline_dual":
        def make(iters):
            def fn(rewards, lam0):
                return dual_descent(rewards, costs32,
                                    float(chains.costs.mean()) * N_REQ_NEARLINE,
                                    lam0, max_iters=iters)

            return DryRunCell(
                arch_id=ARCH_ID, shape_name=shape, kind="serve", fn=fn,
                arg_specs=(sds((N_REQ_NEARLINE, j), jnp.float32),
                           sds((), jnp.float32)),
                in_shardings=(P(BATCH, None), P()),
                meta={"model_flops": 200.0 * N_REQ_NEARLINE * j * 4.0},
            )

        cell = make(200)
        cell.variant_fn = lambda n: make(n)
        cell.loop_trips = 200
        cell.loop_period = 1
        return cell

    if shape == "reward_train":
        opt = AdamW()

        def step(state: TrainState, batch: dict):
            l, grads = jax.value_and_grad(
                lambda p: reward_loss(p, cfg, batch))(state.params)
            new_p, new_o = opt.update(grads, state.opt_state, state.params,
                                      1e-3)
            return TrainState(state.step + 1, new_p, new_o), l

        state = jax.eval_shape(lambda p: init_state(p, opt), params)
        sspec = TrainState(step=P(), params=pspec,
                           opt_state=_adam_specs(pspec))
        batch = {
            "context": sds((N_REQ_TRAIN, D_CONTEXT), jnp.float32),
            "model_onehot": sds((N_REQ_TRAIN, cfg.n_stages, cfg.max_models),
                                jnp.float32),
            "scale_multihot": sds((N_REQ_TRAIN, cfg.n_stages,
                                   cfg.n_scale_groups), jnp.float32),
            "label": sds((N_REQ_TRAIN,), jnp.float32),
        }
        bspec = {k: P(BATCH, *(None,) * (v.ndim - 1))
                 for k, v in batch.items()}
        return DryRunCell(
            arch_id=ARCH_ID, shape_name=shape, kind="train", fn=step,
            arg_specs=(state, batch), in_shardings=(sspec, bspec),
            donate=(0,),
            meta={"model_flops": 3.0 * N_REQ_TRAIN * cfg.n_stages
                  * 2.0 * cfg.d_hidden * cfg.d_hidden * 4},
        )

    if shape == "rank_serve":
        dcfg = din_model.DINConfig(item_vocab=10_000_000, cat_vocab=100_000,
                                   user_vocab=1_000_000)
        dparams = jax.eval_shape(lambda k: din_model.init(k, dcfg),
                                 jax.random.PRNGKey(0))
        dspec = jax.tree_util.tree_map(lambda _: P(), dparams)
        b, t, n = RANK_BATCH, dcfg.seq_len, RANK_CANDS
        batch = {
            "hist_ids": sds((b, t), jnp.int32),
            "hist_cats": sds((b, t), jnp.int32),
            "hist_mask": sds((b, t), jnp.float32),
            "user_fields": sds((b, dcfg.n_user_fields), jnp.int32),
        }
        bspec = {k: P(BATCH, None) for k in batch}

        def fn(p, bb, cid, ccat):
            return din_model.score(p, dcfg, bb, cid, ccat)

        return DryRunCell(
            arch_id=ARCH_ID, shape_name=shape, kind="serve", fn=fn,
            arg_specs=(dparams, batch, sds((b, n), jnp.int32),
                       sds((b, n), jnp.int32)),
            in_shardings=(dspec, bspec, P(BATCH, None), P(BATCH, None)),
            out_shardings=P(BATCH, None),
            meta={"model_flops": b * n * din_model.flops_per_item(dcfg)},
        )

    raise KeyError(shape)


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return reward_model_init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    b, k, m, q = 16, cfg.n_stages, cfg.max_models, cfg.n_scale_groups
    mo = np.zeros((b, k, m), np.float32)
    mo[np.arange(b)[:, None], np.arange(k)[None, :],
       rng.integers(0, m, (b, k))] = 1.0
    sh = np.cumsum(np.eye(q)[rng.integers(0, q, (b, k))][..., ::-1],
                   axis=-1)[..., ::-1]
    return {"context": jnp.asarray(rng.normal(size=(b, cfg.d_context)),
                                   jnp.float32),
            "model_onehot": jnp.asarray(mo),
            "scale_multihot": jnp.asarray(sh, jnp.float32),
            "label": jnp.asarray(rng.uniform(0, 5, b), jnp.float32)}


def smoke_loss(params, cfg, batch):
    return reward_loss(params, cfg, batch)
