"""xdeepfm [arXiv:1803.05170]: n_sparse=39 embed_dim=10
cin_layers=200-200-200 mlp=400-400 interaction=CIN.  ~93M-row stacked
table, row-sharded over 'model' like DLRM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import recsys_common as rc
from repro.configs.base import BATCH, DryRunCell, sds
from repro.distributed.sharding import current_mesh
from repro.models.recsys import xdeepfm as model

ARCH_ID = "xdeepfm"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
SKIPPED_SHAPES: dict = {}

PAD_TO = 1024
N_ITEM_FIELDS = 6


def full_config() -> model.XDeepFMConfig:
    return model.XDeepFMConfig()


def smoke_config() -> model.XDeepFMConfig:
    return model.XDeepFMConfig(vocab_sizes=tuple([32] * 39), embed_dim=4,
                               cin_layers=(8, 8), mlp_hidden=(16, 16))


def _abstract(cfg):
    return jax.eval_shape(
        lambda k: model.init(k, cfg, pad_vocab_to=PAD_TO),
        jax.random.PRNGKey(0))


def _pspec(params):
    spec = jax.tree_util.tree_map(lambda _: P(), params)
    spec["tables"]["stacked"] = P(("model", "pod", "data"), None)
    spec["linear"] = P(("model", "pod", "data"), None)
    return spec


def _batch(cfg, b, with_label=True):
    batch = {"sparse": sds((b, cfg.n_sparse), jnp.int32)}
    specs = {"sparse": P(BATCH, None)}
    if with_label:
        batch["label"] = sds((b,), jnp.float32)
        specs["label"] = P(BATCH)
    return batch, specs


def _hybrid_train_cell(cfg, params, pspec, batch, bspec, b) -> DryRunCell:
    """Hybrid optimizer (stateless SGD embeddings + AdamW dense) - the
    DLRM §Perf iteration 3 port."""
    from repro.configs.base import _adam_specs
    from repro.training.optimizer import AdamW
    from repro.training.trainer import TrainState

    opt = AdamW(weight_decay=0.0)
    EMB = ("tables", "linear")

    def split(p):
        return ({k: v for k, v in p.items() if k in EMB},
                {k: v for k, v in p.items() if k not in EMB})

    def step(state: TrainState, bb: dict):
        l, grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, cfg, bb, current_mesh()))(state.params)
        g_emb, g_dense = split(grads)
        p_emb, p_dense = split(state.params)
        new_emb = jax.tree_util.tree_map(
            lambda p, g: (p - 0.04 * g.astype(p.dtype)).astype(p.dtype),
            p_emb, g_emb)
        new_dense, new_opt = opt.update(g_dense, state.opt_state,
                                        p_dense, 1e-3)
        return TrainState(state.step + 1, dict(new_dense, **new_emb),
                          new_opt), l

    emb_p, dense_p = split(params)
    state = jax.eval_shape(
        lambda dp: TrainState(jnp.zeros((), jnp.int32),
                              dict(dp[0], **dp[1]), AdamW().init(dp[0])),
        (dense_p, emb_p))
    dense_spec = {k: v for k, v in pspec.items() if k not in EMB}
    sspec = TrainState(step=P(), params=pspec,
                       opt_state=_adam_specs(dense_spec))
    return DryRunCell(
        arch_id=ARCH_ID, shape_name="train_batch", kind="train",
        fn=step, arg_specs=(state, batch), in_shardings=(sspec, bspec),
        donate=(0,),
        meta={"model_flops": 3.0 * b * model.flops_per_example(cfg),
              "optimizer": "hybrid sgd(emb)+adamw(dense), 2D rows"},
    )


def make_cell(shape: str) -> DryRunCell:
    cfg = full_config()
    params = _abstract(cfg)
    pspec = _pspec(params)
    info = rc.RECSYS_SHAPES[shape]

    if shape == "train_batch":
        batch, bspec = _batch(cfg, info["batch"])
        return _hybrid_train_cell(cfg, params, pspec, batch, bspec,
                                  info["batch"])
    if shape == "retrieval_cand":
        n = info["n_candidates"]
        user = {"sparse": sds((1, cfg.n_sparse), jnp.int32)}
        uspec = {"sparse": P(None, None)}
        cand = sds((n, N_ITEM_FIELDS), jnp.int32)

        def fwd(p, u, c):
            # python-loop chunks bound the (chunk, Hp*m, D) CIN buffer and
            # keep HLO flop counts exact (while-loops undercount); the
            # candidate set pads to 2^20 so chunk*39 ids shard evenly
            n_real = c.shape[0]
            n_pad = 1 << 20
            c = jnp.pad(c, ((0, n_pad - n_real), (0, 0)))
            n_chunks = 32
            cs = n_pad // n_chunks
            outs = [model.retrieval_forward(p, cfg, u,
                                            c[i * cs:(i + 1) * cs],
                                            current_mesh())
                    for i in range(n_chunks)]
            return jnp.concatenate(outs)[:n_real]

        return rc.retrieval_cell(
            ARCH_ID, fwd=fwd, abstract_params=params, param_specs=pspec,
            args=(user, cand), arg_specs=(uspec, P(BATCH, None)),
            flops_fwd=n * model.flops_per_example(cfg))

    b = info["batch"]
    batch, bspec = _batch(cfg, b, with_label=False)

    def fwd(p, bb):
        return model.forward(p, cfg, bb, current_mesh())

    return rc.serve_cell(ARCH_ID, shape, fwd=fwd, abstract_params=params,
                         param_specs=pspec, batch=batch, batch_specs=bspec,
                         flops_fwd=b * model.flops_per_example(cfg))


# smoke ----------------------------------------------------------------------


def init_smoke(key, cfg):
    return model.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    b = 16
    return {"sparse": jnp.asarray(rng.integers(0, 32, (b, cfg.n_sparse)),
                                  jnp.int32),
            "label": jnp.asarray(rng.integers(0, 2, b), jnp.float32)}


def smoke_loss(params, cfg, batch):
    return model.loss_fn(params, cfg, batch)
