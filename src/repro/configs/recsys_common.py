"""Shared cell builders for the recsys architecture configs.

All four recsys archs expose the same shape set:
  train_batch    B=65,536   train_step (AdamW)
  serve_p99      B=512      online-inference forward
  serve_bulk     B=262,144  offline-scoring forward
  retrieval_cand B=1 user x 1,000,000 candidates
"""
from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import BATCH, DryRunCell, _adam_specs
from repro.training.optimizer import AdamW
from repro.training.trainer import TrainState, init_state

RECSYS_SHAPES = {
    "train_batch": dict(batch=65_536, kind="train"),
    "serve_p99": dict(batch=512, kind="serve"),
    "serve_bulk": dict(batch=262_144, kind="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, kind="retrieval"),
}


def train_cell(arch_id: str, shape: str, *, loss_fn: Callable,
               abstract_params, param_specs, batch, batch_specs,
               flops_fwd: float, lr: float = 1e-3) -> DryRunCell:
    opt = AdamW(weight_decay=0.0)

    def step(state: TrainState, b: dict):
        l, grads = jax.value_and_grad(lambda p: loss_fn(p, b))(state.params)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, lr)
        return TrainState(state.step + 1, new_params, new_opt), l

    state = jax.eval_shape(lambda p: init_state(p, opt), abstract_params)
    sspec = TrainState(step=P(), params=param_specs,
                       opt_state=_adam_specs(param_specs))
    return DryRunCell(
        arch_id=arch_id, shape_name=shape, kind="train",
        fn=step, arg_specs=(state, batch),
        in_shardings=(sspec, batch_specs), donate=(0,),
        meta={"model_flops": 3.0 * flops_fwd},  # fwd + bwd
    )


def serve_cell(arch_id: str, shape: str, *, fwd: Callable, abstract_params,
               param_specs, batch, batch_specs,
               flops_fwd: float) -> DryRunCell:
    return DryRunCell(
        arch_id=arch_id, shape_name=shape, kind="serve",
        fn=fwd, arg_specs=(abstract_params, batch),
        in_shardings=(param_specs, batch_specs),
        out_shardings=P(BATCH),
        meta={"model_flops": flops_fwd},
    )


def retrieval_cell(arch_id: str, *, fwd: Callable, abstract_params,
                   param_specs, args, arg_specs,
                   flops_fwd: float) -> DryRunCell:
    return DryRunCell(
        arch_id=arch_id, shape_name="retrieval_cand", kind="retrieval",
        fn=fwd, arg_specs=(abstract_params, *args),
        in_shardings=(param_specs, *arg_specs),
        out_shardings=P(BATCH),
        meta={"model_flops": flops_fwd},
    )
