"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155,
MoE 32 experts top-8.  ~1.3B total / ~0.4B active params.
"""
from __future__ import annotations

import numpy as np

from repro.configs import base
from repro.models import lm

ARCH_ID = "granite-moe-1b-a400m"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPPED_SHAPES = {
    "long_500k": "pure full-attention stack (no sub-quadratic path); "
                 "skipped per brief - see DESIGN.md §5",
}


def full_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID, n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_head=64, d_ff=512, vocab=49155, padded_vocab=49408,
        rope_theta=10_000.0,
        moe=lm.MoEConfig(n_experts=32, top_k=8, d_expert=512),
        tie_embeddings=True, fsdp=True, attn_chunk_q=1024,
        sequence_parallel=True,
    )


def smoke_config() -> lm.LMConfig:
    return lm.LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=32, vocab=128, padded_vocab=128,
        moe=lm.MoEConfig(n_experts=4, top_k=2, d_expert=32),
        dtype="float32", remat=False, fsdp=False,
    )


def make_cell(shape: str) -> base.DryRunCell:
    return base.lm_make_cell(ARCH_ID, full_config(), shape)


def init_smoke(key, cfg):
    return lm.init(key, cfg)


def smoke_batch(rng: np.random.Generator, cfg) -> dict:
    return base.lm_smoke_batch(rng, cfg)


def smoke_loss(params, cfg, batch):
    return lm.loss_fn(params, cfg, batch)
