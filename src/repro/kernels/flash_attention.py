"""Flash attention for TPU (Pallas): causal / GQA / sliding-window / softcap.

VMEM-blocked online softmax (FlashAttention re-thought for the TPU memory
hierarchy, not a CUDA port - see DESIGN.md §3):

  grid = (batch, q_heads, q_blocks, kv_blocks)   kv innermost (sequential)
  q block    (1, 1, block_q, d)    stays resident across the kv loop
  k/v block  (1, 1, block_kv, d)   streamed HBM->VMEM by the pipeline;
                                   GQA folds h -> h // group in the index map
  scratch    acc (block_q, d) f32, m/l (block_q, MIN_LANE) f32 running stats

Block sizes default to 128/256 - lane-dim multiples of 128 so the MXU
(128x128 systolic array) sees aligned tiles.  Fully-masked kv blocks are
skipped with @pl.when (the causal/window speedup).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MIN_LANE = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  softcap: float | None, block_q: int, block_kv: int,
                  kv_blocks: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    # block-level predication: skip kv blocks fully above the causal
    # diagonal or fully outside the sliding window
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        run = run & (q_start - (k_start + block_kv - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bkv)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0, :, :] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "block_q",
                     "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False):
    """q (B, T, H, dh), k/v (B, S, Hkv, dh) -> (B, T, H, dh).

    T and S are padded to block multiples internally; dh should be a
    multiple of 128 on real TPUs (unchecked in interpret mode).
    """
    b, t, h, dh = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    t_pad = (-t) % block_q
    s_pad = (-s) % block_kv
    if t_pad:
        q = jnp.pad(q, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    if s_pad:
        k = jnp.pad(k, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, s_pad), (0, 0), (0, 0)))

    # (B, H, T, dh) layout so heads are a grid dim
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)

    q_blocks = qt.shape[2] // block_q
    kv_blocks = kt.shape[2] // block_kv
    grid = (b, h, q_blocks, kv_blocks)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv,
        kv_blocks=kv_blocks, seq_kv=s)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, dh),
                         lambda bb, hh, qi, ki, g=g: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
            pltpu.VMEM((block_q, MIN_LANE), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = jnp.swapaxes(out, 1, 2)
    return out[:, :t]
