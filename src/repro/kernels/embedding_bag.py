"""Fused EmbeddingBag (Pallas): gather + weighted bag-sum in one pass.

TPU-idiomatic gather: the bag ids are SCALAR-PREFETCHED and drive the
table BlockSpec's index_map, so the pipeline DMAs exactly the embedding
rows that are needed, one (1, D) row per grid step - no (B, L, D)
intermediate ever hits HBM (the jnp path materializes it; that gap is the
kernel's win, and it's what FBGEMM's TBE does on GPU).

  grid = (B, L)    L innermost: accumulate into the bag's output row
  table block (1, D) @ row ids[b, l]   (via scalar prefetch)
  out   block (1, D) @ row b           (revisited across l - accumulate)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  weights: jnp.ndarray | None = None, *,
                  interpret: bool = False) -> jnp.ndarray:
    """table (V, D), ids (B, L) int32, weights (B, L)? -> (B, D) bag sums."""
    bsz, bag_len = ids.shape
    v, d = table.shape

    if weights is not None:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(bsz, bag_len),
            in_specs=[
                pl.BlockSpec((1, 1), lambda b, l, ids: (b, l)),
                pl.BlockSpec((1, d), lambda b, l, ids: (ids[b, l], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda b, l, ids: (b, 0)),
        )

        def kernel(ids_ref, w_ref, row_ref, o_ref):
            l = pl.program_id(1)

            @pl.when(l == 0)
            def _init():
                o_ref[...] = jnp.zeros_like(o_ref)

            o_ref[...] += w_ref[0, 0] * row_ref[...].astype(o_ref.dtype)

        return pl.pallas_call(
            kernel, grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
            interpret=interpret,
        )(ids, weights, table)

    def kernel(ids_ref, row_ref, o_ref):
        l = pl.program_id(1)

        @pl.when(l == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += row_ref[...].astype(o_ref.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, bag_len),
        in_specs=[pl.BlockSpec((1, d), lambda b, l, ids: (ids[b, l], 0))],
        out_specs=pl.BlockSpec((1, d), lambda b, l, ids: (b, 0)),
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        interpret=interpret,
    )(ids, table)
