"""DIN target attention (Pallas): fused [q,k,q-k,q*k] -> MLP -> weighted sum.

The jnp composition materializes the (B, T, 4d) feature tensor in HBM;
here it exists only as a VMEM tile.  The attention MLP weights are tiny
(4d x h1, h1 x h2, h2 x 1) and ride along replicated per grid step.

  grid = (B / block_b,)
  q (block_b, d), keys (block_b, T, d), mask (block_b, T) -> out (block_b, d)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ta_kernel(q_ref, k_ref, m_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
               b3_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # (bb, d)
    keys = k_ref[...].astype(jnp.float32)  # (bb, T, d)
    mask = m_ref[...].astype(jnp.float32)  # (bb, T)
    qb = jnp.broadcast_to(q[:, None, :], keys.shape)
    feat = jnp.concatenate([qb, keys, qb - keys, qb * keys], axis=-1)
    h = jax.nn.sigmoid(
        jax.lax.dot_general(feat, w1_ref[...].astype(jnp.float32),
                            (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[...])
    h = jax.nn.sigmoid(
        jax.lax.dot_general(h, w2_ref[...].astype(jnp.float32),
                            (((2,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b2_ref[...])
    w = (jax.lax.dot_general(h, w3_ref[...].astype(jnp.float32),
                             (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + b3_ref[...])[..., 0]  # (bb, T)
    w = w * mask
    pooled = jnp.einsum("bt,btd->bd", w, keys)
    o_ref[...] = pooled.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def target_attention(q, keys, mask, w1, b1, w2, b2, w3, b3, *,
                     block_b: int = 128, interpret: bool = False):
    """q (B, d), keys (B, T, d), mask (B, T) -> pooled (B, d)."""
    b, t, d = keys.shape
    h1, h2 = w1.shape[1], w2.shape[1]
    pad = (-b) % block_b
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        keys = jnp.pad(keys, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    grid = (q.shape[0] // block_b,)

    out = pl.pallas_call(
        _ta_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((block_b, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_b, t), lambda i: (i, 0)),
            pl.BlockSpec((4 * d, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, h2), lambda i: (0, 0)),
            pl.BlockSpec((h2,), lambda i: (0,)),
            pl.BlockSpec((h2, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], d), keys.dtype),
        interpret=interpret,
    )(q, keys, mask, w1, b1, w2, b2, w3, b3)
    return out[:b]
