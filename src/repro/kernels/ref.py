"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the ground truth the kernels/tests assert against
(``tests/test_kernels_*``).  These double as the CPU execution path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=-1, softcap=None,
                        scale=None):
    """q (B, T, H, dh), k/v (B, S, Hkv, dh) -> (B, T, H, dh). fp32 softmax."""
    b, t, h, dh = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = scale if scale is not None else 1.0 / jnp.sqrt(float(dh))
    qg = q.reshape(b, t, hk, g, dh)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v)
    return out.reshape(b, t, h, dh)


def embedding_bag_ref(table, ids, weights=None):
    """table (V, D), ids (B, L), weights (B, L) or None -> (B, D) sums."""
    rows = jnp.take(table, ids, axis=0)  # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None]
    return jnp.sum(rows, axis=1)


def dot_interact_ref(feats):
    """feats (B, F, D) -> (B, F(F-1)/2) strictly-lower-tri pairwise dots."""
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[-2]
    iu, ju = jnp.tril_indices(f, k=-1)
    return z[:, iu, ju]


def target_attention_ref(q, keys, mask, w1, b1, w2, b2, w3, b3):
    """DIN attention pool: q (B, d), keys (B, T, d), mask (B, T);
    MLP weights w1 (4d, h1), w2 (h1, h2), w3 (h2, 1) -> pooled (B, d)."""
    qb = jnp.broadcast_to(q[:, None, :], keys.shape)
    feat = jnp.concatenate([qb, keys, qb - keys, qb * keys], axis=-1)
    h = jax.nn.sigmoid(feat @ w1 + b1)
    h = jax.nn.sigmoid(h @ w2 + b2)
    w = (h @ w3 + b3)[..., 0]  # (B, T)
    w = w * mask
    return jnp.einsum("bt,btd->bd", w, keys)


def cin_layer_ref(w, x_prev, x0):
    """w (H_out, Hp*m), x_prev (B, Hp, D), x0 (B, m, D) -> (B, H_out, D)."""
    b, hp, d = x_prev.shape
    m = x0.shape[1]
    z = jnp.einsum("bhd,bmd->bhmd", x_prev, x0).reshape(b, hp * m, d)
    return jnp.einsum("oc,bcd->bod", w, z)
