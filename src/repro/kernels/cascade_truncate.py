"""Pallas gather+cumsum truncation kernel for the survivor-compaction
round (cascade/engine.py CompactPlan execution).

One grid step per request.  The request's (group, user) coordinates are
SCALAR-PREFETCHED and drive the table BlockSpecs' index_map, so the
pipeline DMAs exactly the two (1, 1, cap) rows the request needs from
the (G, U, cap) tables - the TPU-idiomatic gather (same structure as
``embedding_bag``).  Inside the kernel the truncation round is pure
VPU/MXU work:

    mask   = p_row < n3[b]                 (survivors of the n3 cut)
    q      = inclusive cumsum of mask      (survivor prefix position)
    keep   = mask & (q <= expose)          (the exposed set)
    out[b] = sum(keep * clicks_row)        (revenue@expose)

The cumsum runs as a triangular-ones matmul so it maps onto the MXU
without any lane-wise scan support; counts are small integers, exact in
float32.  ``interpret=True`` (CPU) runs the same body under the Pallas
interpreter - that path is CI-tested against the lax.scan engine path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_LANES = 128


@functools.partial(jax.jit, static_argnames=("expose", "interpret"))
def compact_truncate_revenue(p_sorted: jnp.ndarray,
                             clicks_sorted: jnp.ndarray,
                             groups: jnp.ndarray, rows: jnp.ndarray,
                             n3: jnp.ndarray, *, expose: int,
                             interpret: bool = False) -> jnp.ndarray:
    """Revenue@expose per request from CompactPlan tables.

    p_sorted (G, U, C) int32 (sentinel >= cap for invalid slots),
    clicks_sorted (G, U, C) float32, groups/rows/n3 (B,) int32.
    Returns (B,) float32.  Matches engine._revenue_compact exactly.
    """
    g_n, u_n, c_n = p_sorted.shape
    b_n = groups.shape[0]
    # pad the lane axis to the TPU tile width; padded slots carry the
    # sentinel (>= any n3 <= cap) so they never survive the n3 cut
    c_pad = max(_LANES, ((c_n + _LANES - 1) // _LANES) * _LANES)
    if c_pad != c_n:
        p_sorted = jnp.pad(p_sorted, ((0, 0), (0, 0), (0, c_pad - c_n)),
                           constant_values=c_n)
        clicks_sorted = jnp.pad(clicks_sorted,
                                ((0, 0), (0, 0), (0, c_pad - c_n)))

    def kernel(g_ref, r_ref, n3_ref, p_ref, c_ref, o_ref):
        b = pl.program_id(0)
        thr = n3_ref[b]
        p = p_ref[0, 0, :].reshape(1, c_pad)
        m = (p < thr).astype(jnp.float32)
        # inclusive cumsum as a triangular matmul: q[c] = sum_{c'<=c} m
        r_ids = jax.lax.broadcasted_iota(jnp.int32, (c_pad, c_pad), 0)
        c_ids = jax.lax.broadcasted_iota(jnp.int32, (c_pad, c_pad), 1)
        tri = (r_ids <= c_ids).astype(jnp.float32)
        q = jnp.dot(m, tri, preferred_element_type=jnp.float32)
        keep = m * (q <= expose)
        o_ref[0, 0] = jnp.sum(keep * c_ref[0, 0, :].reshape(1, c_pad))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b_n,),
        in_specs=[
            pl.BlockSpec((1, 1, c_pad), lambda b, g, r, n3: (g[b], r[b], 0)),
            pl.BlockSpec((1, 1, c_pad), lambda b, g, r, n3: (g[b], r[b], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, g, r, n3: (b, 0)),
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b_n, 1), jnp.float32),
        interpret=interpret,
    )(groups.astype(jnp.int32), rows.astype(jnp.int32),
      n3.astype(jnp.int32), p_sorted, clicks_sorted)
    return out[:, 0]
