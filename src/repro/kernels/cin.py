"""xDeepFM CIN layer (Pallas): fused outer-product + compression.

The jnp path materializes Z = x_prev (x) x0 of shape (B, Hp*m, D) in HBM
(for the assigned config: 200*39*10 floats/sample).  The kernel forms Z
tile-by-tile in VMEM and contracts it with the compression weights on the
MXU immediately.

  grid = (B / block_b, H_out / block_h)
  x_prev (block_b, Hp, D), x0 (block_b, m, D), w (block_h, Hp*m)
  -> out (block_b, block_h, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cin_kernel(xp_ref, x0_ref, w_ref, o_ref):
    xp = xp_ref[...].astype(jnp.float32)  # (bb, Hp, D)
    x0 = x0_ref[...].astype(jnp.float32)  # (bb, m, D)
    bb, hp, d = xp.shape
    m = x0.shape[1]
    z = (xp[:, :, None, :] * x0[:, None, :, :]).reshape(bb, hp * m, d)
    w = w_ref[...].astype(jnp.float32)  # (bh, Hp*m)
    o = jax.lax.dot_general(w, z, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bh, bb, D)
    o_ref[...] = jnp.swapaxes(o, 0, 1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "block_h", "interpret"))
def cin_layer(w: jnp.ndarray, x_prev: jnp.ndarray, x0: jnp.ndarray, *,
              block_b: int = 64, block_h: int = 64,
              interpret: bool = False) -> jnp.ndarray:
    """w (H_out, Hp*m), x_prev (B, Hp, D), x0 (B, m, D) -> (B, H_out, D)."""
    b, hp, d = x_prev.shape
    m = x0.shape[1]
    h_out = w.shape[0]

    pad_b = (-b) % block_b
    pad_h = (-h_out) % block_h
    if pad_b:
        x_prev = jnp.pad(x_prev, ((0, pad_b), (0, 0), (0, 0)))
        x0 = jnp.pad(x0, ((0, pad_b), (0, 0), (0, 0)))
    if pad_h:
        w = jnp.pad(w, ((0, pad_h), (0, 0)))
    grid = (x_prev.shape[0] // block_b, w.shape[0] // block_h)

    out = pl.pallas_call(
        _cin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, hp, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_b, m, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((block_h, hp * m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_h, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((x_prev.shape[0], w.shape[0], d),
                                       x_prev.dtype),
        interpret=interpret,
    )(x_prev, x0, w)
    return out[:b, :h_out]
