"""DLRM dot-interaction (Pallas): fused pairwise dots + lower-tri extract.

One batch tile per grid step: the (bb, F, D) tile lives in VMEM, the
(F, F) Gram matrix is computed per-sample on the MXU, and only the
F(F-1)/2 strictly-lower-triangular entries (static index list, resolved at
trace time) are written out - the full (B, F, F) never round-trips HBM,
which is the kernel's bytes win over the jnp composition.

  grid = (B / block_b,)
  feats block (block_b, F, D);  out block (block_b, P), P = F(F-1)/2
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _dot_interact_kernel(f_ref, tri_ref, o_ref, *, n_f: int):
    x = f_ref[...].astype(jnp.float32)  # (bb, F, D)
    z = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)  # (bb, F, F)
    flat = z.reshape(x.shape[0], n_f * n_f)
    # compaction gather along the minor dim (static index VECTOR, passed as
    # an input because pallas kernels cannot capture array constants)
    o_ref[...] = jnp.take(flat, tri_ref[...], axis=1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dot_interact(feats: jnp.ndarray, *, block_b: int = 128,
                 interpret: bool = False) -> jnp.ndarray:
    """feats (B, F, D) -> (B, F(F-1)/2) strictly-lower-tri pairwise dots."""
    b, f, d = feats.shape
    iu, ju = np.tril_indices(f, k=-1)
    tri_flat = (iu * f + ju).astype(np.int32)
    p = len(tri_flat)

    pad = (-b) % block_b
    if pad:
        feats = jnp.pad(feats, ((0, pad), (0, 0), (0, 0)))
    grid = (feats.shape[0] // block_b,)

    out = pl.pallas_call(
        functools.partial(_dot_interact_kernel, n_f=f),
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((p,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_b, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((feats.shape[0], p), feats.dtype),
        interpret=interpret,
    )(feats, jnp.asarray(tri_flat))
    return out[:b]
