"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` -
the kernel body runs in Python exactly as written, which validates the
block logic; on TPU they compile natively.  ``INTERPRET`` is resolved
once from the backend.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.cin import cin_layer as _cin
from repro.kernels.dot_interact import dot_interact as _dot_interact
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag
from repro.kernels.flash_attention import flash_attention as _flash_attention

INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _flash_attention(q, k, v, **kw)


def embedding_bag(table, ids, weights=None, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _embedding_bag(table, ids, weights, **kw)


def dot_interact(feats, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _dot_interact(feats, **kw)


def target_attention(q, keys, mask, w1, b1, w2, b2, w3, b3, **kw):
    from repro.kernels.target_attention import target_attention as _ta
    kw.setdefault("interpret", INTERPRET)
    return _ta(q, keys, mask, w1, b1, w2, b2, w3, b3, **kw)


def cin_layer(w, x_prev, x0, **kw):
    kw.setdefault("interpret", INTERPRET)
    return _cin(w, x_prev, x0, **kw)


def cascade_truncate(p_sorted, clicks_sorted, groups, rows, n3, **kw):
    from repro.kernels.cascade_truncate import compact_truncate_revenue
    kw.setdefault("interpret", INTERPRET)
    return compact_truncate_revenue(p_sorted, clicks_sorted, groups, rows,
                                    n3, **kw)


# the oracles, re-exported so callers can assert parity in one import
references = ref
