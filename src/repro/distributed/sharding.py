"""Sharding helpers shared by the model zoo and the launchers.

Models never hard-code a mesh: they call ``constrain(x, axes...)`` which is
an identity outside a mesh context (CPU unit tests) and a
``with_sharding_constraint`` under ``jax.set_mesh`` (dry-run / production).

Axis-name conventions (see launch/mesh.py):
  pod    - slowest axis, crosses ICI-over-DCN boundaries (multi-pod DP)
  data   - in-pod data parallel (+ FSDP for LM params)
  model  - tensor / expert / vocab / embedding-row parallel
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")  # logical batch axis = pod x data

# Serving request axis: the fused ServingPipeline shard_maps its window
# pass over a 1-D ("req",) mesh (launch.mesh.make_request_mesh); guard
# prefix sums and dual consumption stitch across it with
# all_gather/psum.  One name, shared by mesh builders and the pipeline.
REQUEST_AXIS = "req"


def ordered_psum(x, axis_name: str):
    """Cross-shard sum with a shard-ORDER-DETERMINISTIC reduction.

    ``jax.lax.psum`` lowers to the backend allreduce, whose reduction
    order (and therefore float rounding) differs between XLA's
    in-process collectives and the cross-process gloo/NCCL rings - the
    same window summed by an 8-device single process and by 8
    one-device processes can disagree in the last ulp, which breaks
    the serving path's bitwise lambda/spend parity gates.  Gathering
    first and summing locally pins one association: ``all_gather`` is
    pure data movement (no arithmetic), and the local ``jnp.sum`` over
    the leading shard axis compiles to the same reduction everywhere,
    so every host of a multi-process mesh - and the identically-sharded
    single-process reference - produces bit-identical replicated sums.

    Use at the serving path's cross-shard seams (guard spends, dual
    consumption, per-axis spend reports) where bitwise multi-host
    agreement matters more than allreduce bandwidth; ``pmax``/``pmin``
    are order-invariant and need no such pinning.
    """
    return jnp.sum(jax.lax.all_gather(x, axis_name), axis=0)


from repro.distributed.compat import current_mesh  # noqa: F401 (re-export)


def mesh_axes(mesh=None) -> tuple:
    m = mesh or current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def batch_spec(mesh=None) -> tuple:
    """The tuple of axes the batch dim shards over, filtered to the mesh."""
    names = mesh_axes(mesh)
    got = tuple(a for a in BATCH_AXES if a in names)
    return got if got else None


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity w/o a mesh.

    spec entries: None, an axis name, or a tuple of axis names.  Axis names
    not present in the ambient mesh are dropped (lets the same model code
    run on the single-pod and multi-pod meshes).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return jax.lax.with_sharding_constraint(x, P(*(keep(s) for s in spec)))


def constrain_batch(x):
    """Shard the leading dim over (pod, data); replicate the rest."""
    mesh = current_mesh()
    if mesh is None:
        return x
    bs = batch_spec(mesh)
    return constrain(x, bs, *([None] * (x.ndim - 1)))


def named_sharding(mesh, *spec):
    return jax.sharding.NamedSharding(mesh, P(*spec))
