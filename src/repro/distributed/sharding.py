"""Sharding helpers shared by the model zoo and the launchers.

Models never hard-code a mesh: they call ``constrain(x, axes...)`` which is
an identity outside a mesh context (CPU unit tests) and a
``with_sharding_constraint`` under ``jax.set_mesh`` (dry-run / production).

Axis-name conventions (see launch/mesh.py):
  pod    - slowest axis, crosses ICI-over-DCN boundaries (multi-pod DP)
  data   - in-pod data parallel (+ FSDP for LM params)
  model  - tensor / expert / vocab / embedding-row parallel
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")  # logical batch axis = pod x data

# Serving request axis: the fused ServingPipeline shard_maps its window
# pass over a 1-D ("req",) mesh (launch.mesh.make_request_mesh); guard
# prefix sums and dual consumption stitch across it with
# all_gather/psum.  One name, shared by mesh builders and the pipeline.
REQUEST_AXIS = "req"


from repro.distributed.compat import current_mesh  # noqa: F401 (re-export)


def mesh_axes(mesh=None) -> tuple:
    m = mesh or current_mesh()
    return tuple(m.axis_names) if m is not None else ()


def batch_spec(mesh=None) -> tuple:
    """The tuple of axes the batch dim shards over, filtered to the mesh."""
    names = mesh_axes(mesh)
    got = tuple(a for a in BATCH_AXES if a in names)
    return got if got else None


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity w/o a mesh.

    spec entries: None, an axis name, or a tuple of axis names.  Axis names
    not present in the ambient mesh are dropped (lets the same model code
    run on the single-pod and multi-pod meshes).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return jax.lax.with_sharding_constraint(x, P(*(keep(s) for s in spec)))


def constrain_batch(x):
    """Shard the leading dim over (pod, data); replicate the rest."""
    mesh = current_mesh()
    if mesh is None:
        return x
    bs = batch_spec(mesh)
    return constrain(x, bs, *([None] * (x.ndim - 1)))


def named_sharding(mesh, *spec):
    return jax.sharding.NamedSharding(mesh, P(*spec))
