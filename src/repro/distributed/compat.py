"""Version compatibility for jax mesh / shard_map APIs.

The codebase is written against the explicit-sharding era APIs
(``jax.set_mesh``, ``jax.sharding.AxisType``, ``jax.shard_map`` with
``check_vma``); older runtimes (jax 0.4.x) expose the same machinery as
the legacy global-mesh context manager, ``jax.experimental.shard_map``
(``check_rep``) and ``jax.make_mesh`` without ``axis_types``.  Everything
mesh-shaped in this repo goes through the four helpers here so a single
source tree runs on both.
"""
from __future__ import annotations

import contextlib

import jax


def make_mesh(shape: tuple, axes: tuple):
    """``jax.make_mesh`` with Auto axis types when the runtime has them."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(shape))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


@contextlib.contextmanager
def mesh_context(mesh):
    """Ambient-mesh context: ``jax.set_mesh`` or the legacy ``with mesh:``."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` or the experimental one (check_vma ~ check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def current_mesh():
    """The ambient mesh (set_mesh or legacy context), or None."""
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and m.axis_names:
            return m
    except AttributeError:
        pass
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
        if m is not None and m.axis_names and not m.empty:
            return m
    except (ImportError, AttributeError):
        pass
    return None
