"""Gradient compression for the DP all-reduce (pod x data axes).

Three modes, all expressed with jax-native collectives (shard_map +
ppermute/psum - not an NCCL emulation):

  * ``bf16``    - cast-psum-cast: 2x wire bytes vs fp32, unbiased enough
                  in practice (stochastic rounding noted as future work);
  * ``int8``    - ring reduce-scatter + all-gather via ppermute with
                  per-chunk fp32 scales re-quantized at every hop;
                  8x fewer wire bytes on the reduce phase, wire cost
                  shows up as collective-permute in the HLO (the roofline
                  harness counts it);
  * ``topk_ef`` - top-k magnitude sparsification with ERROR FEEDBACK
                  (EF21-style): the residual state carries what was not
                  transmitted into the next step, preserving convergence.

All functions operate on a single flat fp32 vector; ``compress_tree`` /
``uncompress_tree`` handle pytrees by flatten/concat (standard bucketing).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Flat <-> tree plumbing
# ---------------------------------------------------------------------------


def flatten_tree(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat


def unflatten_like(flat, tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.ndim else 1
        out.append(flat[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# int8 quantization primitive
# ---------------------------------------------------------------------------


def quantize_int8(x: jnp.ndarray, block: int = 2048):
    """Per-block symmetric int8: returns (q int8 (n,), scales f32 (n/block,))."""
    n = x.shape[0]
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(xp), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(xp / jnp.maximum(scale, 1e-30)), -127, 127)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, n: int,
                    block: int = 2048):
    x = q.astype(jnp.float32).reshape(-1, block) * scales[:, None]
    return x.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# Ring all-reduce with int8 wire format (shard_map body)
# ---------------------------------------------------------------------------


def _ring_allreduce_int8_body(x, *, axis: str, size: int, block: int):
    """x: (n,) identical-shape local shard contribution. Classic 2(S-1)-step
    ring: reduce-scatter (quantized hops) then all-gather (quantized).
    ``size`` is the static axis size (the caller reads it off the mesh;
    jax.lax.axis_size is not available on every supported jax)."""
    s = size
    me = jax.lax.axis_index(axis)
    n = x.shape[0]
    chunk = -(-n // s)  # ceil
    xp = jnp.pad(x, (0, chunk * s - n)).reshape(s, chunk)

    perm = [(i, (i + 1) % s) for i in range(s)]

    # reduce-scatter: after S-1 hops, chunk (me+1)%s holds the full sum
    def rs_step(i, acc):
        send_idx = (me - i) % s
        q, sc = quantize_int8(acc[send_idx], block)
        q_r = jax.lax.ppermute(q, axis, perm)
        sc_r = jax.lax.ppermute(sc, axis, perm)
        recv = dequantize_int8(q_r, sc_r, chunk, block)
        recv_idx = (me - i - 1) % s
        return acc.at[recv_idx].add(recv)

    acc = jax.lax.fori_loop(0, s - 1, rs_step, xp)

    # all-gather: circulate each shard's reduced chunk
    def ag_step(i, acc):
        send_idx = (me - i + 1) % s
        q, sc = quantize_int8(acc[send_idx], block)
        q_r = jax.lax.ppermute(q, axis, perm)
        sc_r = jax.lax.ppermute(sc, axis, perm)
        recv = dequantize_int8(q_r, sc_r, chunk, block)
        recv_idx = (me - i) % s
        return acc.at[recv_idx].set(recv)

    acc = jax.lax.fori_loop(0, s - 1, ag_step, acc)
    return acc.reshape(-1)[:n]


def ring_allreduce_int8(flat_grads, mesh, *, axis: str = "data",
                        block: int = 2048):
    """Sum ``flat_grads`` (replicated layout, per-device distinct values is
    the caller's contract under shard_map-of-training) across ``axis``."""
    from repro.distributed.compat import shard_map

    body = partial(_ring_allreduce_int8_body, axis=axis,
                   size=int(mesh.shape[axis]), block=block)
    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=P(axis), check_vma=False)(flat_grads)


# ---------------------------------------------------------------------------
# bf16 psum + top-k error feedback (mesh-agnostic forms)
# ---------------------------------------------------------------------------


def psum_bf16(tree, axis_name):
    """Cast-to-bf16 all-reduce (use inside shard_map/pmap)."""
    return jax.tree_util.tree_map(
        # gf: allow[GF001] training-only gradient compression: bf16 is
        # lossy by design and never feeds a serving decision, so ring
        # order is irrelevant here
        lambda g: jax.lax.psum(g.astype(jnp.bfloat16), axis_name)
        .astype(g.dtype), tree)


@dataclass
class TopKEFState:
    residual: jnp.ndarray  # flat fp32


def topk_ef_init(params) -> TopKEFState:
    n = sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))
    return TopKEFState(jnp.zeros((n,), jnp.float32))


def topk_ef_compress(flat_grad: jnp.ndarray, state: TopKEFState,
                     k_frac: float = 0.01):
    """Local step of EF top-k: returns (sparse-as-dense update, new state).
    The dense masked vector is what gets all-reduced; untransmitted mass
    stays in the residual."""
    g = flat_grad + state.residual
    k = max(1, int(k_frac * g.shape[0]))
    thresh = jax.lax.top_k(jnp.abs(g), k)[0][-1]
    mask = jnp.abs(g) >= thresh
    sent = jnp.where(mask, g, 0.0)
    return sent, TopKEFState(residual=g - sent)
