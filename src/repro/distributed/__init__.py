"""Distributed runtime: sharding rules, collective helpers, compression."""
