"""Multi-host request mesh: bring-up + cross-host window routing.

The serving pipeline scales past one host by sharding the REQUEST axis
across a ``jax.distributed`` process group: ``initialize`` wires this
process into the group (gloo collectives on CPU, NCCL/ICI elsewhere),
``make_request_mesh`` then spans every process's devices, and
``MultihostSource`` routes each traffic window so that every host
GENERATES its own slice instead of receiving it over the wire.

The routing protocol (the tentpole invariant of the multi-host path):

  1. Window t's arrival list is a pure function of ``(seed, t)``
     (``RequestSource.arrivals``), so every host computes the FULL
     global list for free - no request is ever shipped between hosts.
  2. Every host derives the same padded layout from ``(n, bucket)``
     alone (``serving.pipeline.window_layout``), so the global row ->
     request permutation agrees bitwise everywhere.
  3. ``launch.mesh.process_shard_rows`` tells this host which padded
     row ranges its devices own; the host materializes contexts and
     compact score tables for exactly those requests
     (``RequestSource.window_for_users``) and sentinel-fills its pad
     rows the same way ``ServingPipeline._pad_chunk_tables`` does.
  4. The fused pass runs as one SPMD program over the process-spanning
     mesh: per-request work stays device-local while the guard
     prefix-sums, per-axis spends and the nearline dual update stitch
     globally over deterministic ``ordered_psum``/``all_gather``
     collectives - every host agrees BITWISE on lambda and on every
     decision, with zero steady-state recompiles per host.

Elasticity follows training/elastic.py's reshard-on-restore posture:
``jax.distributed`` cannot change world size in-band, so a host
join/leave checkpoints the tiny stream state (window cursor + dual
chain + seed), re-forms the mesh at the new size and REPLAYS the
in-flight window - windows are pure ``(seed, t)`` functions, so the
resumed stream picks up exactly where the old one stopped.

Single-host remains the default everywhere: ``initialize`` is a no-op
without a coordinator, and nothing in this module imports at serve
time unless multi-host is requested.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

import numpy as np

# -- process-group bring-up -------------------------------------------------

_ENV_COORD = "GREENFLOW_COORDINATOR"
_ENV_NPROC = "GREENFLOW_NUM_PROCESSES"
_ENV_PID = "GREENFLOW_PROCESS_ID"


def initialize(*, coordinator: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None,
               local_device_ids=None) -> bool:
    """Join the ``jax.distributed`` process group (no-op single-host).

    Arguments default to the ``GREENFLOW_COORDINATOR`` /
    ``GREENFLOW_NUM_PROCESSES`` / ``GREENFLOW_PROCESS_ID`` environment
    variables, so a launcher can configure children purely through the
    environment.  Returns True when the group was joined (after which
    ``jax.devices()`` spans every process and ``make_request_mesh``
    builds the process-spanning request mesh), False when running
    single-process.  MUST be called before any other jax API touches
    the backend.
    """
    if coordinator is None:
        coordinator = os.environ.get(_ENV_COORD) or None
    if num_processes is None:
        num_processes = int(os.environ.get(_ENV_NPROC, "1"))
    if process_id is None:
        pid_env = os.environ.get(_ENV_PID)
        process_id = int(pid_env) if pid_env is not None else None
    if coordinator is None or int(num_processes) <= 1:
        return False
    import jax

    try:  # CPU backends stitch cross-host collectives over gloo
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:  # non-CPU backend or older jax: default transport
        pass
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(num_processes),
        process_id=None if process_id is None else int(process_id),
        local_device_ids=local_device_ids)
    return True


def host_report() -> dict:
    """This process's view of the group: identity + device census.

    The per-host provenance block of BENCH_multihost.json and the
    ``host`` label on flight-recorder rows both come from here.
    """
    import jax

    local = jax.local_devices()
    return {
        "process_index": int(jax.process_index()),
        "process_count": int(jax.process_count()),
        "local_devices": len(local),
        "global_devices": len(jax.devices()),
        "platform": local[0].platform if local else "none",
    }


def host_label(index: int | None = None) -> str:
    """Canonical per-host label (``host0``, ``host1``, ...) used by the
    flight recorder's JSONL rows and Perfetto process names."""
    if index is None:
        import jax

        index = jax.process_index()
    return f"host{int(index)}"


# -- cross-host window routing ---------------------------------------------


@dataclass
class HostWindowSlice:
    """This host's slice of one globally laid-out window.

    ``valid``/``k_of`` cover the host's LOCAL padded rows (one
    contiguous ``b/S`` block per addressable device, in mesh order);
    ``n``/``b`` are the GLOBAL request count and padded bucket every
    host agrees on.  ``ServingPipeline.serve_window(..., shard=...)``
    consumes this instead of re-deriving the layout from ``len(rows)``.
    """

    n: int  # global request count of the window
    b: int  # global padded bucket (window_bucket(n))
    valid: np.ndarray  # (local_rows,) float32 1=real request, 0=pad
    k_of: np.ndarray | None = None  # (local_rows,) int32 tenant ids
    rows_global: np.ndarray | None = None  # (local_rows,) global row ids

    @property
    def local_rows(self) -> int:
        return int(len(self.valid))


class MultihostSource:
    """Route an inner ``RequestSource`` across the request mesh.

    Wraps any source with ``arrivals``/``window_for_users`` (generated
    or replayed) and a mesh-attached ``ServingPipeline``; ``window(t,
    n)`` produces the WindowChunk for THIS host's slice of the global
    window - contexts and compact tables for only the rows its devices
    own, sentinel-padded exactly like the single-process padded path,
    plus the ``HostWindowSlice`` that tells ``serve_window`` the global
    layout.  Drop-in for ``run_stream``'s ``source`` argument.
    """

    def __init__(self, inner, pipeline):
        from repro.launch.mesh import mesh_num_shards

        if pipeline.mesh is None:
            raise ValueError("MultihostSource needs a mesh-attached "
                             "pipeline (ServingPipeline(mesh=...))")
        if getattr(pipeline, "_cap", None) is None:
            raise ValueError("multihost routing needs the compact (k3) "
                             "table layout; this pipeline runs the "
                             "generic scan kernel")
        self.inner = inner
        self.pipeline = pipeline
        self.mesh = pipeline.mesh
        self.n_shards = mesh_num_shards(self.mesh)
        # forwarded so run_stream/launchers treat this like any source
        self.chains = getattr(inner, "chains", None)
        self.expose = getattr(inner, "expose", None)
        self.seed = getattr(inner, "seed", None)

    @property
    def universe(self):
        return self.inner.universe

    def arrivals(self, t: int, n: int) -> np.ndarray:
        return self.inner.arrivals(t, n)

    def window(self, t: int, n: int):
        """THIS host's chunk of global window t (see module docstring
        for the routing protocol)."""
        from repro.data.request_source import WindowChunk
        from repro.launch.mesh import process_shard_rows
        from repro.serving.pipeline import window_layout

        pipe = self.pipeline
        users = np.asarray(self.inner.arrivals(t, n))
        b = pipe.window_bucket(n)
        t_n = (None if pipe.tenant_budgets is None
               else len(pipe.tenant_budgets))
        perm, valid, k_of = window_layout(n, b, t_n)

        slices = process_shard_rows(self.mesh, b)
        rows_global = np.concatenate(
            [np.arange(lo, hi, dtype=np.intp) for lo, hi in slices])
        valid_l = valid[rows_global]
        mask = valid_l > 0
        perm_l = perm[rows_global]

        # materialize ONLY this host's real requests, then scatter them
        # into the sentinel-padded local rows (pad rows: zero context,
        # cap-filled p, zero ck - the same fill _pad_chunk_tables uses,
        # masked out by ``valid`` before anything reads them)
        part = self.inner.window_for_users(users[perm_l[mask]])
        ctx_m = np.asarray(part.ctx, np.float32)
        p_m = np.asarray(part.tables["p"], np.int32)
        ck_m = np.asarray(part.tables["ck"], np.float32)

        n_local = len(rows_global)
        ctx_l = np.zeros((n_local, ctx_m.shape[1]), np.float32)
        ctx_l[mask] = ctx_m
        g_n, _, cap = p_m.shape
        p_l = np.full((g_n, n_local, cap), pipe._cap, np.int32)
        p_l[:, mask, :] = p_m
        ck_l = np.zeros((g_n, n_local, cap), np.float32)
        ck_l[:, mask, :] = ck_m

        # per-device LOCAL row ids: each shard gathers within its own
        # b/S-row table slice, so rows restart at 0 on every device
        per = b // self.n_shards
        rows_l = np.tile(np.arange(per, dtype=np.int32), len(slices))

        shard = HostWindowSlice(
            n=int(n), b=int(b), valid=valid_l.astype(np.float32),
            k_of=None if k_of is None else
            np.asarray(k_of[rows_global], np.int32),
            rows_global=rows_global.astype(np.int64))
        return WindowChunk(ctx=ctx_l, rows=rows_l,
                           tables={"p": p_l, "ck": ck_l},
                           users=None,
                           h2d_bytes=int(getattr(part, "h2d_bytes", 0)),
                           shard=shard)


class ShiftedSource:
    """``inner`` with its window clock shifted by ``t0``.

    ``run_stream`` always counts windows from 0; a resumed stream
    serves ``sizes[t0:]``, so the source must map local window t back
    to GLOBAL window ``t + t0`` - arrivals are pure ``(seed, t)``
    functions, so the shifted source replays exactly the traffic the
    interrupted run would have served next.  Wrap the inner source
    BEFORE handing it to ``MultihostSource``.
    """

    def __init__(self, inner, t0: int):
        self.inner = inner
        self.t0 = int(t0)
        self.chains = getattr(inner, "chains", None)
        self.expose = getattr(inner, "expose", None)
        self.seed = getattr(inner, "seed", None)

    @property
    def universe(self):
        return self.inner.universe

    def arrivals(self, t: int, n: int) -> np.ndarray:
        return self.inner.arrivals(t + self.t0, n)

    def window(self, t: int, n: int):
        return self.inner.window(t + self.t0, n)

    def window_for_users(self, users: np.ndarray):
        return self.inner.window_for_users(users)


# -- elastic re-sharding (reshard-on-restore for the stream) ---------------


@dataclass
class StreamCheckpoint:
    """The tiny durable state of a streaming run: everything a NEW
    process group (any size) needs to resume bitwise-consistently.

    Windows are pure ``(seed, t)`` functions, so no request data is
    saved - only the cursor of the next window to serve, the dual
    chain (host values of lambda_t and the recorded entry price), and
    the source seed.  The in-flight window at checkpoint time is NOT in
    ``t_next``: the restarted group replays it (same seed, same t ->
    same traffic, same decisions).
    """

    t_next: int
    lam: object  # host pytree of the published price chain
    lam_rec: object
    seed: int
    n_shards: int  # world size that WROTE this (provenance only)


def _host_value(arr):
    """A (possibly multi-process, fully-replicated) array's host value."""
    if getattr(arr, "is_fully_addressable", True):
        return np.asarray(arr)
    return np.asarray(arr.addressable_shards[0].data)


def checkpoint_stream(path: str, pipeline, *, t_next: int,
                      seed: int) -> str:
    """Write a ``StreamCheckpoint`` for ``pipeline``'s dual chain.

    Atomic (write + rename) and host-independent: every host holds the
    same replicated chain, so any ONE host's write is the truth - in a
    multi-host run call on process 0 only, or on all (last rename
    wins, bytes identical).
    """
    import jax

    from repro.launch.mesh import mesh_num_shards

    to_list = lambda x: np.asarray(_host_value(x), np.float64).tolist()
    blob = {
        "t_next": int(t_next),
        "lam": jax.tree_util.tree_map(to_list, pipeline.lam),
        "lam_rec": jax.tree_util.tree_map(to_list, pipeline._lam_rec),
        "seed": int(seed),
        "n_shards": int(mesh_num_shards(pipeline.mesh)),
    }
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(blob, f)
    os.replace(tmp, path)
    return path


def restore_stream(path: str, pipeline) -> StreamCheckpoint:
    """Load a ``StreamCheckpoint`` into ``pipeline``'s dual chain.

    The new group may be ANY size (reshard-on-restore): lambda is
    replicated state, so restoring it onto a different mesh is a plain
    broadcast - the pipeline re-replicates lazily on the next
    multi-host window.  Returns the checkpoint; the caller resumes the
    stream at ``t_next`` (serving windows ``sizes[t_next:]``).
    """
    import jax
    import jax.numpy as jnp

    with open(path) as f:
        blob = json.load(f)
    back = lambda old, saved: jnp.asarray(
        np.asarray(saved, np.float32).reshape(np.shape(_host_value(old))))
    pipeline.lam = jax.tree_util.tree_map(
        lambda old, saved: back(old, saved), pipeline.lam, blob["lam"])
    pipeline._lam_rec = jax.tree_util.tree_map(
        lambda old, saved: back(old, saved), pipeline._lam_rec,
        blob["lam_rec"])
    pipeline._mh_lam = False  # re-replicate on the next multi-host window
    return StreamCheckpoint(
        t_next=int(blob["t_next"]), lam=blob["lam"],
        lam_rec=blob["lam_rec"], seed=int(blob["seed"]),
        n_shards=int(blob["n_shards"]))
