"""Low-overhead metrics registry: counters, gauges, log2 histograms.

Pure Python, designed around two hot-path facts of the serving loop:

  * ENABLED instruments are bound once (``counter(...)`` /
    ``instrument.labels(...)`` return cached children) and updated with
    one attribute add under a per-instrument lock - a handful of
    sub-microsecond operations per WINDOW (never per request), far
    below the <2% overhead budget the bench gate enforces.
  * A DISABLED registry hands out ONE shared null instrument whose
    methods are argument-swallowing no-ops: the hot path performs no
    allocations, takes no locks, and touches no shared state.  The
    zero-overhead test in tests/test_obs.py pins this.

Label support is positional-free: ``labels(bucket=..., axis=...)``
keys a child by the sorted (key, value) tuple, so ``tenant``/``region``
/``axis``/``bucket`` attributions share one metric name (the Prometheus
convention).  Histograms use FIXED log2 bucket edges chosen at
registration - observation is a bisect over a small tuple, no
allocation, no dynamic bucketing.

Exporters: ``prometheus_text`` (text exposition format v0.0.4) and
``snapshot`` (a JSON-able dict, one entry per metric, each with its
type/help/unit and every labeled child's value).
"""
from __future__ import annotations

import threading
from bisect import bisect_left


def log2_edges(lo: float, hi: float) -> tuple[float, ...]:
    """Fixed histogram edges: powers of two from ``lo`` up to >= ``hi``
    (plus the implicit +Inf overflow bucket)."""
    edges = []
    e = float(lo)
    while e < hi:
        edges.append(e)
        e *= 2.0
    edges.append(e)
    return tuple(edges)


class _NullInstrument:
    """The shared no-op instrument of a disabled registry.

    Every mutation method swallows its arguments and returns
    immediately; ``labels`` returns the same singleton, so bound
    children are free too.  Stateless and therefore trivially safe to
    share across threads.
    """

    __slots__ = ()

    def labels(self, **kv) -> "_NullInstrument":
        return self

    def inc(self, amount=1):
        pass

    def set(self, value):
        pass

    def observe(self, value):
        pass


NULL_INSTRUMENT = _NullInstrument()


class _Child:
    """One labeled series of an instrument (the unlabeled series is the
    child with an empty label tuple)."""

    __slots__ = ("parent", "key", "value", "bucket_counts", "sum")

    def __init__(self, parent, key):
        self.parent = parent
        self.key = key  # sorted ((label, value), ...) tuple
        self.value = 0.0
        if parent.kind == "histogram":
            self.bucket_counts = [0] * (len(parent.edges) + 1)
            self.sum = 0.0

    def inc(self, amount=1):
        with self.parent.lock:
            self.value += amount

    def set(self, value):
        with self.parent.lock:
            self.value = float(value)

    def observe(self, value):
        v = float(value)
        i = bisect_left(self.parent.edges, v)
        with self.parent.lock:
            self.bucket_counts[i] += 1
            self.value += 1  # observation count
            self.sum += v

    def labels(self, **kv):  # re-labeling a child refines its key
        return self.parent.labels(**dict(self.key), **kv)


class Instrument:
    """A named metric: a family of labeled ``_Child`` series."""

    __slots__ = ("name", "kind", "help", "unit", "edges", "lock",
                 "children")

    def __init__(self, name: str, kind: str, help: str = "",
                 unit: str = "", edges: tuple[float, ...] = ()):
        self.name = name
        self.kind = kind
        self.help = help
        self.unit = unit
        self.edges = tuple(float(e) for e in edges)
        self.lock = threading.Lock()
        self.children: dict[tuple, _Child] = {}

    def labels(self, **kv) -> _Child:
        key = tuple(sorted((k, str(v)) for k, v in kv.items()))
        child = self.children.get(key)
        if child is None:
            with self.lock:
                child = self.children.get(key)
                if child is None:
                    child = _Child(self, key)
                    self.children[key] = child
        return child

    # unlabeled convenience: instrument IS its default child
    def inc(self, amount=1):
        self.labels().inc(amount)

    def set(self, value):
        self.labels().set(value)

    def observe(self, value):
        self.labels().observe(value)


class MetricsRegistry:
    """The one namespace every serving metric registers under.

    ``MetricsRegistry(enabled=False)`` is the zero-overhead form: every
    ``counter``/``gauge``/``histogram`` call returns the shared
    ``NULL_INSTRUMENT`` and nothing is ever recorded.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self._instruments: dict[str, Instrument] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind: str, help: str, unit: str,
             edges: tuple[float, ...] = ()):
        if not self.enabled:
            return NULL_INSTRUMENT
        inst = self._instruments.get(name)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(name)
                if inst is None:
                    inst = Instrument(name, kind, help, unit, edges)
                    self._instruments[name] = inst
        if inst.kind != kind:
            raise ValueError(f"metric {name!r} already registered as "
                             f"{inst.kind}, not {kind}")
        return inst

    def counter(self, name: str, help: str = "", unit: str = ""):
        return self._get(name, "counter", help, unit)

    def gauge(self, name: str, help: str = "", unit: str = ""):
        return self._get(name, "gauge", help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  edges: tuple[float, ...] = ()):
        return self._get(name, "histogram", help, unit,
                         edges or log2_edges(1.0, 4096.0))

    # -- export ----------------------------------------------------------

    def snapshot(self) -> dict:
        """JSON-able view: {metric: {type, help, unit, series: [...]}}.
        Histogram series carry bucket edge -> cumulative count pairs."""
        out = {}
        for name, inst in sorted(self._instruments.items()):
            series = []
            with inst.lock:
                children = list(inst.children.items())
            for key, child in sorted(children):
                entry = {"labels": dict(key), "value": child.value}
                if inst.kind == "histogram":
                    cum, buckets = 0, {}
                    for e, c in zip(inst.edges, child.bucket_counts):
                        cum += c
                        buckets[f"{e:g}"] = cum
                    buckets["+Inf"] = cum + child.bucket_counts[-1]
                    entry.update(count=int(child.value), sum=child.sum,
                                 buckets=buckets)
                series.append(entry)
            out[name] = {"type": inst.kind, "help": inst.help,
                         "unit": inst.unit, "series": series}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition of every registered metric."""
        lines = []
        for name, m in self.snapshot().items():
            if m["help"]:
                lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} {m['type']}")
            for s in m["series"]:
                lab = _fmt_labels(s["labels"])
                if m["type"] == "histogram":
                    for edge, cum in s["buckets"].items():
                        le = _fmt_labels({**s["labels"], "le": edge})
                        lines.append(f"{name}_bucket{le} {cum}")
                    lines.append(f"{name}_sum{lab} {s['sum']:g}")
                    lines.append(f"{name}_count{lab} {s['count']}")
                else:
                    lines.append(f"{name}{lab} {s['value']:g}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


NULL_REGISTRY = MetricsRegistry(enabled=False)
