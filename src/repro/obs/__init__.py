"""Observability: the flight recorder for the streaming serving path.

One ``Obs`` bundle threads three views of a run through the stack:

  * ``Obs.metrics`` - a ``MetricsRegistry`` (counters / gauges / log2
    histograms) replacing the scattered ad-hoc counters with one
    namespace.  Prometheus-text and JSON snapshot exporters.
  * ``Obs.tracer`` - host span tracing (``obs.span("prep")``) with a
    Chrome-trace-event exporter; a run opens in Perfetto with the
    serving thread and the ``chunk-prefetch`` thread on separate
    tracks, so the overlap/stall story is literally visible.
  * ``Obs.events`` - an optional per-window JSONL flight log (size,
    bucket, lam per named axis, spend vs budget per axis, FLOPs,
    gCO2e, h2d bytes, prep/stall/submit ms, recompile deltas).

Everything is opt-in and free when off: the shared ``NULL_OBS`` (what
``get_obs(None)`` returns, and what every instrumented constructor
defaults to) hands out stateless no-op instruments and spans - no
allocations, no locks, no branches beyond one method call - and the
telemetry parity tests pin that enabled runs are BITWISE identical
(decisions, lam traces, spends) to disabled runs: nothing in here reads
a device array until the stream has been drained.

Metric namespace
----------------
All serving metrics live under the ``greenflow_`` prefix.  Labels are
free-form key/values; the conventional ones are ``axis`` (a
``CompiledSpec`` axis name such as ``tenant[3]`` or ``region_a``),
``bucket`` (padded window shape), ``tenant``, ``region``.

========================================  =========  ====== ===========
name                                      type       unit   labels
========================================  =========  ====== ===========
greenflow_windows_total                   counter    1      -
greenflow_requests_total                  counter    1      -
greenflow_window_size                     histogram  1      -
greenflow_prep_ms                         histogram  ms     -
greenflow_stall_ms                        histogram  ms     -
greenflow_submit_ms                       histogram  ms     -
greenflow_h2d_bytes_total                 counter    bytes  -
greenflow_compiles_total                  counter    1      -
greenflow_downgraded_total                counter    1      -
greenflow_bucket_windows_total            counter    1      bucket
greenflow_table_cache_hits_total          counter    1      -
greenflow_table_cache_misses_total        counter    1      -
greenflow_lambda                          gauge      1/cost axis
greenflow_spend                           gauge      FLOPs  axis
greenflow_budget                          gauge      FLOPs  axis
greenflow_flops_total                     counter    FLOPs  [name]
greenflow_energy_kwh_total                counter    kWh    [name]
greenflow_gco2e_total                     counter    g      [name]
greenflow_ledger_windows_total            counter    1      [name]
========================================  =========  ====== ===========

Counters/histograms are updated once per WINDOW on the serving thread
(never per request); lam/spend/budget gauges and the JSONL event log
are written once per RUN after the stream drains, because reading them
earlier would force a device sync mid-stream.  Carbon counters are
incremented when the ``CarbonLedger`` meters its parked windows
(lazily, at report time), keeping metering off the response path.
"""
from __future__ import annotations

from repro.obs.events import WindowEventLog, window_event
from repro.obs.env import env_info
from repro.obs.metrics import (MetricsRegistry, NULL_INSTRUMENT,
                               NULL_REGISTRY, log2_edges)
from repro.obs.trace import (NULL_SPAN, NULL_TRACER, Tracer,
                             merge_chrome_traces)

MS_EDGES = log2_edges(0.25, 8192.0)


class Obs:
    """The telemetry bundle handed to every instrumented component.

    ``Obs()`` is fully on (in-memory registry + tracer, no file
    sinks); attach ``events=WindowEventLog(path)`` for the JSONL
    flight log and call ``export(path)`` / ``tracer.write(path)`` for
    the Prometheus/Perfetto artifacts.  ``NULL_OBS`` is the shared
    disabled bundle - components take ``obs=None`` and normalize via
    ``get_obs``.
    """

    def __init__(self, *, metrics: MetricsRegistry | None = None,
                 tracer: Tracer | None = None,
                 events: WindowEventLog | None = None,
                 interval: int = 0, annotate: bool = False,
                 host: str | None = None):
        self.metrics = MetricsRegistry() if metrics is None else metrics
        self.tracer = (Tracer(annotate=annotate, process_label=host)
                       if tracer is None else tracer)
        self.events = events
        self.interval = int(interval)
        self.host = host  # per-host label of a multi-host run
        self.enabled = self.metrics.enabled or self.tracer.enabled

    def span(self, name: str, **args):
        return self.tracer.span(name, **args)

    # -- end-of-run flush (safe: stream already drained) -----------------

    def flush_stream(self, stats, *, cs=None, ledger=None) -> None:
        """Set per-axis lam/spend/budget gauges from the final window
        and append one JSONL event per window.  Called by ``run_stream``
        AFTER its drain loop, so the device reads here never stall the
        serving path."""
        if not self.enabled or not stats.windows:
            return
        rows = [window_event(t, r, s, cs=cs, ledger=ledger,
                             host=self.host)
                for t, (r, s) in enumerate(zip(stats.windows,
                                               stats.submit_ms))]
        last = rows[-1]
        for metric, key in (("greenflow_lambda", "lam"),
                            ("greenflow_spend", "spend"),
                            ("greenflow_budget", "budget")):
            vals = last[key]
            if vals:
                g = self.metrics.gauge(metric)
                for axis, v in vals.items():
                    g.labels(axis=axis).set(v)
        if self.events is not None:
            self.events.write_rows(rows)

    def live_line(self, t: int, result, submit_ms: float) -> str:
        """Compact one-window terminal line (host-side fields only)."""
        return (f"[obs] w={t:<5d} n={int(result.n_valid):<7d} "
                f"bucket={result.bucket} prep={result.prep_ms:6.1f}ms "
                f"stall={result.stall_ms:6.1f}ms "
                f"submit={submit_ms:6.1f}ms "
                f"compiles={int(result.compiles)} "
                f"h2d={int(result.h2d_bytes)}B")

    # -- snapshot export --------------------------------------------------

    def export(self, metrics_out: str) -> tuple[str, str]:
        """Write the Prometheus text snapshot to ``metrics_out`` and the
        JSON snapshot next to it at ``metrics_out + '.json'``."""
        import json
        import os
        path = os.path.abspath(metrics_out)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            f.write(self.metrics.prometheus_text())
        jpath = path + ".json"
        with open(jpath, "w") as f:
            json.dump(self.metrics.snapshot(), f, indent=2)
        return path, jpath


NULL_OBS = Obs(metrics=NULL_REGISTRY, tracer=NULL_TRACER)


def get_obs(obs: Obs | None) -> Obs:
    """Normalize an optional ``obs`` argument: ``None`` -> ``NULL_OBS``."""
    return NULL_OBS if obs is None else obs


__all__ = [
    "Obs", "NULL_OBS", "get_obs",
    "MetricsRegistry", "NULL_REGISTRY", "NULL_INSTRUMENT", "log2_edges",
    "Tracer", "NULL_TRACER", "NULL_SPAN", "MS_EDGES",
    "merge_chrome_traces",
    "WindowEventLog", "window_event", "env_info",
]
