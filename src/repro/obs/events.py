"""Per-window JSONL event log: the flight-recorder record of a run.

One JSON object per served window, written after the stream has been
drained (device arrays are read only AFTER the run forces them, so the
exporter never injects a sync into the response path):

    {"window": 3, "n": 160, "bucket": [160, false, true], "compiles": 0,
     "lam": {"tenant[0]": 1.1e-05, ..., "region_a": 0.0},
     "spend": {"tenant[0]": 1.9e8, ...}, "budget": {...},
     "flops": 2.4e9, "gco2e": 0.81, "revenue": 118.0,
     "h2d_bytes": 84480, "prep_ms": 11.2, "stall_ms": 0.0,
     "submit_ms": 2.9, "downgraded": 0}

``lam``/``spend``/``budget`` are keyed by the pipeline's compiled
ConstraintSpec axis names (``CompiledSpec.k_names`` /
``budget_names``), so a multi-axis run (geotenants) logs every dual
price and every per-axis spend-vs-budget by name.  ``gco2e`` is metered
through the pipeline's CarbonLedger when one is attached (operational
grams at that window's CI), else null.

The log appends across ``run_stream`` calls - a serving process writes
one growing JSONL file - and each line is self-contained, so the file
tails cleanly into any log pipeline.
"""
from __future__ import annotations

import json
import os

import numpy as np


def _names_or(names, fallback: str) -> tuple[str, ...]:
    return tuple(names) if names else (fallback,)


def _host_np(x) -> np.ndarray:
    """Host value of a (possibly multi-process) array.

    Replicated outputs of a ``jax.distributed`` run are not fully
    addressable, so ``np.asarray`` refuses them; every addressable
    shard holds the same replicated bytes, so the first one is the
    value.  Plain arrays/scalars pass straight through."""
    if getattr(x, "is_fully_addressable", True):
        return np.asarray(x)
    return np.asarray(x.addressable_shards[0].data)


def _axis_dict(names, values) -> dict | None:
    """Zip axis names with a scalar-or-vector value into {name: float}."""
    if values is None:
        return None
    arr = np.asarray(values, np.float64).reshape(-1)
    names = tuple(names)
    if len(arr) != len(names):  # scalar broadcast over one axis name
        if arr.size == 1:
            arr = np.full(len(names), float(arr[0]))
        else:
            return {f"k{i}": float(v) for i, v in enumerate(arr)}
    return {n: float(v) for n, v in zip(names, arr)}


def window_event(t: int, result, submit_ms: float | None = None, *,
                 cs=None, ledger=None, host: str | None = None) -> dict:
    """One WindowResult -> one JSON-able event row.

    ``cs`` is the pipeline's ``CompiledSpec`` (names the lam/spend/
    budget axes); ``ledger`` an optional CarbonLedger used to meter the
    window's operational gCO2e at its CI; ``host`` tags the row with
    the writing process's label in a multi-host run (each host logs its
    OWN slice of every window - n/revenue/h2d are per-host there, while
    lam/spend/budget are the globally stitched values every host agrees
    on).  Reads device arrays - call only after the stream has been
    drained.
    """
    lam_names = _names_or(getattr(cs, "k_names", ()), "global")
    bud_names = _names_or(getattr(cs, "budget_names", ()), "global")

    lam = _axis_dict(lam_names, _host_np(result.lam_after))
    if result.tr_spend is not None:  # geotenants: tenant + region axes
        tr = _host_np(result.tr_spend)
        spend = _axis_dict(bud_names,
                           np.concatenate([tr.sum(axis=1),
                                           tr.sum(axis=0)]))
    elif result.region_spend is not None:
        spend = _axis_dict(bud_names, _host_np(result.region_spend))
    elif result.tenant_spend is not None:
        spend = _axis_dict(bud_names, _host_np(result.tenant_spend))
    else:
        spend = {"global": float(np.sum(_host_np(result.spend)))}
    budget = _axis_dict(
        bud_names,
        result.k_budget if result.k_budget is not None else result.budget)

    flops = (None if result.flops is None
             else float(_host_np(result.flops)))
    gco2e = None
    if ledger is not None and flops is not None:
        from repro.core.pfec import energy_from_flops
        gco2e = energy_from_flops(flops, ledger.cfg) * ledger.window_ci(t)

    row = {} if host is None else {"host": str(host)}
    row.update({
        "window": int(t),
        "n": int(result.n_valid),
        "bucket": (None if result.bucket is None
                   else list(result.bucket)),
        "compiles": int(result.compiles),
        "lam": lam,
        "spend": spend,
        "budget": budget,
        "flops": flops,
        "gco2e": gco2e,
        "revenue": float(np.sum(result.revenue_np)),
        "downgraded": int(result.downgraded),
        "h2d_bytes": int(result.h2d_bytes),
        "prep_ms": round(float(result.prep_ms), 3),
        "stall_ms": round(float(result.stall_ms), 3),
        "submit_ms": (None if submit_ms is None
                      else round(float(submit_ms), 3)),
    })
    return row


class WindowEventLog:
    """Appends one JSON line per window to ``path`` (file and parent
    directory created on first write; successive runs keep appending,
    with ``window`` numbered per run)."""

    def __init__(self, path: str):
        self.path = os.path.abspath(path)
        self.rows_written = 0

    def write_rows(self, rows: list[dict]) -> None:
        if not rows:
            return
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        with open(self.path, "a") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")
        self.rows_written += len(rows)
