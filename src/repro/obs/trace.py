"""Span tracing with a Chrome-trace-event (Perfetto) exporter.

``Tracer.span("prep")`` wraps a host-side phase of the serving loop in
a context manager that records one complete ("ph": "X") event: name,
thread id, start timestamp and duration in microseconds.  Spans are
recorded from ANY thread - the streaming driver's prefetch worker and
serving thread land on separate tracks, which is what makes the
overlap/stall story visible in a trace viewer - and recording is a
single ``list.append`` (atomic under the GIL), so the prefetch queue is
never blocked by telemetry.

``chrome_trace()``/``write()`` export the standard Chrome trace-event
JSON object format: load the file in Perfetto (https://ui.perfetto.dev)
or chrome://tracing and every run opens as one timeline, threads named
via ``thread_name`` metadata events.

``Tracer(annotate=True)`` additionally enters a
``jax.profiler.TraceAnnotation`` for every span, so when the driver
also runs ``jax.profiler.trace`` (``launch/serve.py --profile-dir``)
the host spans line up against XLA device events in the same profile.

A disabled tracer (``Tracer(enabled=False)``, or the shared
``NULL_TRACER``) hands back ONE stateless no-op context manager:
``span`` costs a method call, allocates nothing, takes no locks.
"""
from __future__ import annotations

import json
import os
import threading
import time


class _NullSpan:
    """Shared no-op span of a disabled tracer (stateless, reentrant)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0", "annotation")

    def __init__(self, tracer, name, args):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.annotation = None

    def __enter__(self):
        if self.tracer.annotate:
            ann = _trace_annotation(self.name)
            if ann is not None:
                self.annotation = ann
                ann.__enter__()
        self.t0 = self.tracer.clock_ns()
        return self

    def __exit__(self, *exc):
        t1 = self.tracer.clock_ns()
        if self.annotation is not None:
            self.annotation.__exit__(*exc)
        th = threading.current_thread()
        # one append; CPython list.append is atomic, no lock needed
        self.tracer.events.append(
            (self.name, th.ident, th.name, self.t0, t1 - self.t0,
             self.args))
        return False


def _trace_annotation(name):
    """A ``jax.profiler.TraceAnnotation`` when jax is importable (pass-
    through so host spans appear inside a jax.profiler device trace)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # jax absent or too old: spans still record
        return None
    return TraceAnnotation(name)


class Tracer:
    """Collects host spans; exports Chrome trace-event JSON."""

    def __init__(self, enabled: bool = True, *, annotate: bool = False,
                 process_label: str | None = None,
                 clock_ns=time.perf_counter_ns):
        self.enabled = bool(enabled)
        self.annotate = bool(annotate)
        self.process_label = process_label
        self.clock_ns = clock_ns
        self.events: list = []

    def span(self, name: str, **args):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args or None)

    def instant(self, name: str, **args) -> None:
        """A zero-duration marker event on the calling thread."""
        if not self.enabled:
            return
        th = threading.current_thread()
        self.events.append((name, th.ident, th.name, self.clock_ns(), 0,
                            args or None))

    # -- export ----------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome trace-event JSON object: ``traceEvents`` complete
        ("X") events in microseconds plus ``thread_name`` metadata so
        Perfetto labels the serving and prefetch tracks."""
        pid = os.getpid()
        events = list(self.events)  # snapshot (other threads may append)
        out = []
        tids: dict[int, str] = {}
        for name, tid, tname, t0_ns, dur_ns, args in events:
            tids.setdefault(tid, tname)
            ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
                  "ts": t0_ns / 1e3, "dur": dur_ns / 1e3, "cat": "host"}
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            out.append(ev)
        meta = [{"name": "thread_name", "ph": "M", "pid": pid,
                 "tid": tid, "args": {"name": tname}}
                for tid, tname in sorted(tids.items())]
        if self.process_label:  # one named track group per host
            meta.insert(0, {"name": "process_name", "ph": "M",
                            "pid": pid,
                            "args": {"name": self.process_label}})
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def write(self, path: str) -> str:
        """Write the trace JSON; open the file in ui.perfetto.dev."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return str(v)


def merge_chrome_traces(paths, out_path: str | None = None) -> dict:
    """Merge per-host trace files into ONE Chrome trace-event object.

    Each host of a multi-host run writes its own trace
    (``Tracer(process_label=...).write``); pids are distinct processes,
    so concatenating the event lists yields one timeline in which every
    host appears as its own named track group (the ``process_name``
    metadata events survive the merge).  Timestamps are
    ``perf_counter_ns``-based and therefore NOT cross-host aligned -
    the merged view answers "what did each host do", not "who was
    first by a microsecond".
    """
    events: list = []
    for p in paths:
        with open(p) as f:
            events.extend(json.load(f).get("traceEvents", []))
    merged = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out_path is not None:
        d = os.path.dirname(os.path.abspath(out_path))
        os.makedirs(d, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(merged, f)
    return merged


NULL_TRACER = Tracer(enabled=False)
