"""Environment provenance stamp for benchmark artifacts.

``env_info()`` answers "what machine, what stack, what commit produced
these numbers" - every ``BENCH_*.json`` written by ``benchmarks/run.py``
embeds it as an ``env`` block so the perf trajectory across PRs is
interpretable (a 2x "regression" that coincides with a jaxlib bump or a
2-core CI runner is not a regression).
"""
from __future__ import annotations

import os
import subprocess
from datetime import datetime, timezone


def _git_sha() -> str | None:
    try:
        here = os.path.dirname(os.path.abspath(__file__))
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=here,
            capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def env_info() -> dict:
    """Versions, backend, device kind, cores, git SHA, UTC timestamp."""
    info: dict = {
        "cpu_count": os.cpu_count(),
        "git_sha": _git_sha(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
    }
    try:
        import jax
        import jaxlib
        info["jax"] = jax.__version__
        info["jaxlib"] = jaxlib.__version__
        info["backend"] = jax.default_backend()
        devs = jax.devices()
        info["device_kind"] = devs[0].device_kind if devs else None
        info["n_devices"] = len(devs)
    except Exception as e:  # bench provenance must never crash a bench
        info["jax_error"] = repr(e)
    return info
